"""Traffic-adaptive placement controller: observe → sweep → narrow → reconfigure.

This is the serving↔search integration the paper's flow implies (§3.3): the
environment-adaptation loop should pick the low-Watt·s operating point
*automatically*, reacting to what the serving layer is actually doing rather
than to a hand-chosen offline cell. The controller closes that loop:

1. **observe** — snapshot the :class:`~repro.runtime.serving.EngineStats`
   delta since the last sweep: the traffic mix over shape kinds
   (prefill vs decode token shares), the batch occupancy of the scheduler,
   and the tightest per-step time budget implied by pending request SLOs.
   Occupancy is quantized into quarter buckets so observed cells form a
   small stable set and the measurement cache stays hot. Under the
   slot-stream scheduler the window is a **step count** (``interval_steps``
   via the engine's ``on_step_end`` hook — there are no wave boundaries);
   under the wave scheduler it stays ``interval_waves``.
2. **sweep** — map the observed mix to fleet cells (arch × bucketed shape ×
   candidate destination mesh) and run
   :func:`~repro.core.offload_search.search_fleet` over them through an
   :class:`~repro.core.evaluator.EvalEngine` whose cache is disk-persisted
   (:class:`~repro.core.cache_store.PersistentEvalCache`): every sweep in
   every process shares one measurement history, so steady-state traffic
   re-plans with zero new measurements.
3. **narrow** — per shape kind, merge the candidate destinations' frontiers
   into a kind-level :func:`~repro.core.pareto.fleet_frontier` (placements
   dominated by another destination drop out) and run the paper's staged
   mixed-environment selection (:func:`~repro.core.device_select.
   select_destination`) over the surviving destinations in cheap-to-expensive
   order. The user requirement (default: "no worse Watt·s than the cell's
   paper-faithful baseline") early-exits on the first satisfying
   destination; when the observed traffic carries request SLOs the implied
   per-step time budget joins as ``max_time_s`` (multi-requirement §3.3:
   time SLO and energy jointly, as in mixed-destination selection). The
   chosen pattern fixes cell, destination *and* the DVFS clock gene jointly.
4. **reconfigure** — apply the chosen :class:`Placement`s to the engine.
   Under slot streams the swap applies to newly admitted slots (in-flight
   requests keep their admission epoch), so it is safe mid-run; the wave
   scheduler keeps the between-waves-only rule.

``benchmarks/serving_bench.py`` drives this loop under prefill-heavy,
decode-heavy and mixed-burst traffic and reports Watt·s per 1k tokens
against a static placement. ``runtime/router.py`` runs the same loop once
for a whole fleet of engines on mixed destinations; ``docs/ARCHITECTURE.md``
diagrams the full search/serving/telemetry/router data flow.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Optional, Sequence

from repro.configs import SHAPES, ShapeSpec, get_config
from repro.core.device_select import Destination, SelectionReport, \
    select_destination
from repro.core.evaluator import EvalEngine, VectorizedExecutor
from repro.core.cache_store import PersistentEvalCache
from repro.core.fitness import Measurement, UserRequirement
from repro.core.ga import GAConfig
from repro.core.lm_cost_model import Decisions, measure_cell
from repro.core.offload_search import CellSpec, FleetResult, lm_cell_key, \
    mesh_label, search_fleet
from repro.core.pareto import ParetoPoint, fleet_frontier, frontier_by_cell, \
    select_operating_point
from repro.core.power import TpuPowerModel
from repro.runtime.serving import Placement, ServingEngine

# Shape catalog the observer maps live traffic onto: one production cell per
# serving shape kind ("train" cells are the offline fleet's business).
DEFAULT_CATALOG: dict[str, ShapeSpec] = {
    "prefill": SHAPES["prefill_32k"],
    "decode": SHAPES["decode_32k"],
}

# Candidate destination meshes (single source for the serve CLI and the
# serving benchmark): the production single-pod slice and its 2-pod variant.
DEFAULT_MESH_OPTIONS: tuple[dict[str, int], ...] = (
    {"data": 16, "model": 16},
    {"pod": 2, "data": 16, "model": 16},
)

_INFEASIBLE = Measurement(time_s=0.0, energy_ws=0.0, feasible=False)


@dataclass(frozen=True)
class TrafficMix:
    """One observation window of engine traffic."""

    kind_weights: tuple[tuple[str, float], ...]  # token share per shape kind
    occupancy: float  # mean active-slot fraction over the window
    occupancy_bucket: float  # quantized to quarters (cache-stable cells)
    tokens: int  # tokens seen in the window
    # tightest per-step time budget implied by pending request SLOs (None
    # when no queued/in-flight request carries one) — joins the narrowing
    # requirement as max_time_s
    slo_time_per_step_s: Optional[float] = None
    # wall-clock (or virtual-clock) seconds the window covered — set when
    # the observer is driven on a clock (FleetRouter.observe(now=...));
    # None on the legacy clockless paths. With it, the mix carries the
    # observed arrival *rate*, which is what energy-proportional
    # autoscaling sizes the awake set against.
    window_s: Optional[float] = None

    def weight(self, kind: str) -> float:
        return dict(self.kind_weights).get(kind, 0.0)

    @property
    def tokens_per_s(self) -> Optional[float]:
        """Observed token throughput demand over the window (None without
        a clocked window)."""
        if self.window_s is None or self.window_s <= 0.0:
            return None
        return self.tokens / self.window_s


def occupancy_bucket(occupancy: float) -> float:
    """Quantize occupancy to (0.25, 0.5, 0.75, 1.0] quarters."""
    if occupancy <= 0.0:
        return 0.25
    return min(1.0, math.ceil(occupancy * 4) / 4)


def scale_shape(base: ShapeSpec, bucket: float) -> ShapeSpec:
    """Catalog shape scaled to an observed batch-occupancy bucket (shared by
    the per-engine controller and the fleet router, so both map the same
    traffic onto the same cache-stable cells)."""
    gb = max(1, int(round(base.global_batch * bucket)))
    if gb == base.global_batch:
        return base
    return replace(base, name=f"{base.name}@occ{int(bucket * 100)}",
                   global_batch=gb)


def narrowing_requirement(
    *,
    base: Optional[UserRequirement],
    require_energy_improvement: bool,
    baseline_energy_ws: float,
    live: Optional[Placement],
    ref_tokens: int,
    slo_time_per_step_s: Optional[float],
) -> Optional[UserRequirement]:
    """The §3.3 narrowing requirement shared by the per-engine controller
    and the fleet router.

    With no explicit ``base`` requirement and ``require_energy_improvement``
    set, narrow to placements at least as good (Watt·s) as the cell's
    paper-faithful ``baseline_energy_ws`` AND no worse per token than the
    ``live`` placement currently applied — an occupancy-scaled cell's own
    baseline can be less efficient per token than the live placement
    (smaller batches amortize the fixed parameter traffic over fewer
    tokens), and adopting it would make "adaptive" lose to static. A
    pending-SLO per-step time budget joins as ``max_time_s`` (a cell
    measurement covers ``ref_tokens`` tokens and a serving step consumes
    one token per request, so the budget scales by ``ref_tokens``) — the
    multi-requirement case: time SLO and energy jointly."""
    req = base
    if req is None and require_energy_improvement:
        cap = baseline_energy_ws
        if live is not None:
            cap = min(cap, live.energy_per_token_ws * ref_tokens)
        req = UserRequirement(max_energy_ws=cap)
    if slo_time_per_step_s is not None:
        cap_t = slo_time_per_step_s * ref_tokens
        if req is None:
            req = UserRequirement(max_time_s=cap_t)
        elif req.max_time_s is None or req.max_time_s > cap_t:
            req = replace(req, max_time_s=cap_t)
    return req


@dataclass
class PlanReport:
    """Introspection record of one observe→sweep→narrow→reconfigure pass."""

    mix: TrafficMix
    fleet: Optional[FleetResult]
    selections: dict[str, SelectionReport] = field(default_factory=dict)
    placements: dict[str, Placement] = field(default_factory=dict)
    new_measurements: int = 0


def _chips(mesh_shape: dict[str, int]) -> int:
    n = 1
    for v in mesh_shape.values():
        n *= v
    return n


def static_placements(
    arch: str,
    mesh_shape: dict[str, int],
    *,
    catalog: Optional[dict[str, ShapeSpec]] = None,
    power: TpuPowerModel = TpuPowerModel(),
    destination: Optional[str] = None,
) -> dict[str, Placement]:
    """Paper-faithful default placement (``Decisions()`` at nominal clock on
    one fixed mesh) — the static baseline the adaptive loop competes with.
    ``destination`` overrides the reported label (the fleet router labels
    placements with catalog destination names, not raw mesh labels);
    ``power`` prices the cell on that destination's silicon."""
    cfg = get_config(arch)
    out: dict[str, Placement] = {}
    for kind, shape in (catalog or DEFAULT_CATALOG).items():
        m = measure_cell(cfg, shape, mesh_shape, Decisions(), power=power)
        tokens = max(shape.tokens(), 1)
        out[kind] = Placement(
            kind=kind, cell=lm_cell_key(cfg, shape, mesh_shape),
            destination=destination or mesh_label(mesh_shape),
            decisions=Decisions(),
            clock=1.0, energy_per_token_ws=m.energy_ws / tokens,
            time_per_token_s=m.time_s / tokens, source="static")
    return out


class PlacementController:
    """Drives ``search_fleet`` placement from the live serving loop.

    Attach to a :class:`ServingEngine` and every ``interval_waves`` waves the
    controller re-plans from the traffic observed since its last sweep. All
    sweeps share ``eval_engine``'s (optionally disk-persisted) measurement
    cache.
    """

    def __init__(
        self,
        engine: ServingEngine,
        arch: str,
        mesh_options: Sequence[dict[str, int]],
        *,
        cache_path: Optional[str] = "results/eval_cache.jsonl",
        cache_compact: bool = True,
        eval_engine: Optional[EvalEngine] = None,
        ga_config: Optional[GAConfig] = None,
        requirement: Optional[UserRequirement] = None,
        require_energy_improvement: bool = True,
        catalog: Optional[dict[str, ShapeSpec]] = None,
        power: TpuPowerModel = TpuPowerModel(),
        interval_waves: int = 4,
        interval_steps: int = 32,
        min_kind_weight: float = 0.02,
        prefer: str = "energy",
        drift_threshold: float = 0.2,
        calibrate_ledger: bool = True,
    ) -> None:
        if not mesh_options:
            raise ValueError("need at least one candidate destination mesh")
        self.engine = engine
        self.arch = arch
        self.cfg = get_config(arch)
        self.mesh_options = [dict(m) for m in mesh_options]
        if eval_engine is None:
            if cache_path:
                # cache_compact=False is the safe setting when SEVERAL live
                # processes share one cache file: construction-time
                # compaction unlinks the file under a concurrent appender's
                # open handle (see CacheStore.load); single-writer
                # deployments keep the default and their results/ file
                # stops accumulating duplicate/torn lines
                eval_engine = EvalEngine(
                    executor=VectorizedExecutor(),
                    cache=PersistentEvalCache(cache_path,
                                              compact=cache_compact))
            else:
                eval_engine = EvalEngine(executor=VectorizedExecutor())
        self.eval_engine = eval_engine
        self.ga_config = ga_config or GAConfig(population=10, generations=8)
        self.requirement = requirement
        self.require_energy_improvement = require_energy_improvement
        self.catalog = dict(catalog or DEFAULT_CATALOG)
        self.power = power
        self.interval_waves = interval_waves
        self.interval_steps = interval_steps
        self.min_kind_weight = min_kind_weight
        self.prefer = prefer
        self.drift_threshold = drift_threshold
        self.calibrate_ledger = calibrate_ledger
        self.drift: dict[str, float] = {}  # kind -> (metered/modeled) - 1
        self.history: list[PlanReport] = []
        self._last_stats = engine.stats.snapshot()
        self._waves_since = 0
        self._steps_since = 0
        self._resweep_pending = False

    # -- wiring --------------------------------------------------------
    def attach(self) -> "PlacementController":
        """Register on the engine's observation hooks: ``on_wave_end``
        (wave scheduler, ``interval_waves`` window) and ``on_step_end``
        (slot streams have no wave boundaries — the window is
        ``interval_steps`` engine steps). Each scheduler only fires its own
        hook, so the windows never double-count."""
        self.engine.on_wave_end = self._on_wave_end
        if hasattr(self.engine, "on_step_end"):
            self.engine.on_step_end = self._on_step_end
        return self

    def _on_wave_end(self, engine: ServingEngine) -> None:
        self._waves_since += 1
        if self._resweep_pending or self._waves_since >= self.interval_waves:
            self._waves_since = 0
            self._resweep_pending = False
            self.update()

    def _on_step_end(self, engine: ServingEngine) -> None:
        self._steps_since += 1
        if self._resweep_pending or self._steps_since >= self.interval_steps:
            self._steps_since = 0
            self._resweep_pending = False
            self.update()

    # -- metered feedback (telemetry drift hook) -----------------------
    def note_metered(self, kind: str, metered_ws_per_token: float) -> bool:
        """Feed a *metered* Watt·s/token (telemetry/meter.py over live
        traffic) back into the loop for one shape kind.

        Two effects: the engine's energy ledger is recalibrated by the
        metered/modeled ratio (so accumulated Watt·s track the measurement,
        not the model), and when the drift exceeds ``drift_threshold`` a
        re-sweep is scheduled for the next between-waves point regardless of
        ``interval_waves`` — the model the current placement was chosen by
        has been falsified by measurement, so the choice itself is suspect.
        Returns True when a re-sweep was triggered.
        """
        p = self.engine.placements.get(kind)
        if p is None or p.energy_per_token_ws <= 0.0 \
                or metered_ws_per_token <= 0.0:
            # a zero metered rate is a failed/empty measurement, not a free
            # placement — correcting the ledger by 0 would stop it entirely
            return False
        ratio = metered_ws_per_token / p.energy_per_token_ws
        self.drift[kind] = ratio - 1.0
        if self.calibrate_ledger:
            self.engine.energy_correction[kind] = ratio
        if abs(ratio - 1.0) > self.drift_threshold:
            self._resweep_pending = True
            return True
        return False

    # -- observe -------------------------------------------------------
    def observe(self) -> TrafficMix:
        """Traffic mix since the previous observation (consumes the window)."""
        cur = self.engine.stats
        last = self._last_stats
        prefill = cur.prefill_tokens - last.prefill_tokens
        decode = cur.decode_tokens - last.decode_tokens
        slot_steps = cur.slot_steps - last.slot_steps
        active = cur.active_slot_steps - last.active_slot_steps
        self._last_stats = cur.snapshot()
        total = prefill + decode
        weights = (("prefill", prefill / total if total else 0.0),
                   ("decode", decode / total if total else 0.0))
        occ = active / slot_steps if slot_steps else 0.0
        slo_fn = getattr(self.engine, "slo_time_per_step_s", None)
        return TrafficMix(kind_weights=weights, occupancy=occ,
                          occupancy_bucket=occupancy_bucket(occ),
                          tokens=total,
                          slo_time_per_step_s=slo_fn() if slo_fn else None)

    def shape_for(self, kind: str, bucket: float) -> ShapeSpec:
        """Catalog shape scaled to the observed batch-occupancy bucket."""
        return scale_shape(self.catalog[kind], bucket)

    # -- sweep + narrow ------------------------------------------------
    def plan(self, mix: TrafficMix) -> PlanReport:
        """Sweep the observed cells and pick per-kind placements jointly:
        cell (observed kind × occupancy), destination (candidate mesh) and
        operating point (pattern incl. DVFS clock)."""
        report = PlanReport(mix=mix, fleet=None)
        kinds = [k for k in self.catalog
                 if mix.weight(k) > self.min_kind_weight]
        if not kinds:
            return report

        cells = [CellSpec.create(self.arch,
                                 self.shape_for(kind, mix.occupancy_bucket),
                                 mesh)
                 for kind in kinds for mesh in self.mesh_options]
        fleet = search_fleet(cells, ga_config=self.ga_config,
                             engine=self.eval_engine, cell_workers=1,
                             power=self.power)
        report.fleet = fleet
        report.new_measurements = fleet.evaluations

        for kind in kinds:
            kind_results = [cr for cr in fleet.cells
                            if cr.spec.shape.kind == kind]
            placement = self._narrow_kind(kind, kind_results, fleet, report,
                                          mix=mix)
            if placement is not None:
                report.placements[kind] = placement
        return report

    def _narrow_kind(self, kind: str, kind_results, fleet: FleetResult,
                     report: PlanReport,
                     mix: Optional[TrafficMix] = None) -> Optional[Placement]:
        """Feed the kind-level fleet frontier through the paper's staged
        destination selection; returns None to keep the current placement."""
        if not kind_results:
            return None
        # placements dominated across destinations drop out here: a mesh
        # whose whole frontier is dominated contributes nothing downstream
        kfront = fleet_frontier(cr.search.frontier for cr in kind_results)
        by_cell = frontier_by_cell(kfront)

        ref = next((cr for cr in kind_results
                    if cr.spec.mesh_shape == self.mesh_options[0]),
                   kind_results[0])
        ref_tokens = max(ref.spec.shape.tokens(), 1)
        # default §3.3 requirement: at least as good (Watt·s) as the default
        # destination's paper-faithful baseline for this cell AND no worse
        # per token than the live placement, with any pending-SLO time
        # budget joining as max_time_s (see narrowing_requirement)
        req = narrowing_requirement(
            base=self.requirement,
            require_energy_improvement=self.require_energy_improvement,
            baseline_energy_ws=ref.search.baseline.energy_ws,
            live=self.engine.placements.get(kind),
            ref_tokens=ref_tokens,
            slo_time_per_step_s=(mix.slo_time_per_step_s
                                 if mix is not None else None))

        def make_search(cr):
            points = by_cell.get(cr.cell, [])

            def _search():
                pt = select_operating_point(points, req, prefer=self.prefer)
                if pt is None:
                    return None, _INFEASIBLE
                return pt, pt.measurement

            return _search

        destinations = [
            Destination(name=mesh_label(cr.spec.mesh_shape),
                        # stand-in verification cost: bigger slices are the
                        # expensive-to-verify targets (paper: CPU < GPU < FPGA)
                        verify_cost_s=float(_chips(cr.spec.mesh_shape)),
                        search=make_search(cr))
            for cr in kind_results
            # a mesh whose whole frontier is dominated drops out before
            # staged verification — no verify cost is ever charged for it
            if cr.cell in by_cell
        ]
        if not destinations:
            return None
        selection = select_destination(destinations, requirement=req)
        report.selections[kind] = selection
        if selection.chosen is None:
            return None
        chosen_pt = selection.patterns[selection.chosen]
        if not isinstance(chosen_pt, ParetoPoint):
            return None
        cr = next(c for c in kind_results
                  if mesh_label(c.spec.mesh_shape) == selection.chosen)
        dec = fleet.decisions_for(chosen_pt)
        tokens = max(cr.spec.shape.tokens(), 1)
        return Placement(
            kind=kind, cell=chosen_pt.cell, destination=selection.chosen,
            decisions=dec, clock=dec.clock,
            energy_per_token_ws=chosen_pt.energy_ws / tokens,
            time_per_token_s=chosen_pt.time_s / tokens, source="adaptive")

    # -- reconfigure ---------------------------------------------------
    def update(self) -> PlanReport:
        """One full observe → sweep → narrow → reconfigure pass."""
        mix = self.observe()
        report = self.plan(mix)
        self.history.append(report)
        if report.placements:
            self.engine.reconfigure({**self.engine.placements,
                                     **report.placements})
            for kind in report.placements:
                # a fresh placement resets the metered feedback: the old
                # correction ratio belonged to the placement it was measured
                # against, and applying it to the new one would skew the
                # ledger until the next note_metered
                self.engine.energy_correction.pop(kind, None)
                self.drift.pop(kind, None)
        return report
