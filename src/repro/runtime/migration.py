"""Mid-flight migration of admitted requests across destinations.

The fleet could only act on *queued* requests: once admitted, a request was
pinned to its slot until finish, even when its destination was
fleet-dominated or saturating — which capped what ``FleetRouter.rebalance``
could save under a traffic spike. This module unpins it:

* :func:`snapshot_slot` pulls ONE slot's share of a live engine's decode
  state to host numpy — per-slot KV rows, recurrent RWKV/Mamba/hybrid
  leaves, the per-slot position, the request, its cursor and its effective
  length cap — into a :class:`SlotSnapshot`. The pull is **mesh-agnostic**
  (``np.asarray`` gathers a sharded array), so the snapshot crosses
  destinations with different meshes/layouts, the way the checkpoint module
  restores a checkpoint onto a rescaled mesh.
* :func:`restore_slot` reshapes the snapshot onto the target's geometry —
  cache-length-bearing leaves (``models/transformer.decode_state_cache_keys``)
  are padded/truncated with the checkpointer's :func:`~repro.checkpoint.
  checkpointer.resize_axis` when ``max_len`` disagrees; truncation is safe
  because the per-row causal mask makes rows at index >= pos unreachable —
  and masked-writes it into a free slot via
  ``models/transformer.restore_decode_slot`` (the restore-side dual of
  ``reset_decode_slots``): the target's other slots keep decoding.
* :func:`migrate` is the transactional move (snapshot → restore → detach,
  in an order that leaves the source untouched when the target refuses).

Billing contract (no token billed twice): tokens decoded before the move
billed under the slot's epoch on the source; tokens after the move bill
under the **target's** placement epoch captured at restore. The move itself
bills as a separate transfer-cost ledger line
(``EngineStats.migration_ws`` = snapshot bytes x ``transfer_ws_per_mib``,
charged to the receiving engine). ``admissions`` is NOT re-counted — the
fleet ledger sees one admission per request regardless of how often it
moves; ``migrations_in``/``migrations_out`` record the events.

Serving equivalence: the snapshot carries the slot's **cap** (``max_len``
of the admitting engine, chained through re-migration), so a request moved
to a roomier destination still length-caps exactly where its
never-migrated baseline would. ``tests/test_migration.py`` pins the
stronger property: output tokens and finish reasons are byte-identical to
the never-migrated baseline across all five model families, with
migrations forced at step 0, mid-decode and one-token-before-eos.

Deterministic refusals (:class:`MigrationError`), never silent corruption:
a sliding-window ring whose length differs between engines (ring phase is
length-dependent), a target cache too short for the rows the request can
still address, a non-awake target without a clock to wake-charge it, or a
wake whose latency has not elapsed. The caller retries after the wake.

Thread-safety: single-writer, inherited from ``ServingEngine``'s contract —
migration mutates both engines, so the caller must own both. The lockstep
``FleetExecutor`` runs migrations on the coordinator thread at tick
barriers (its ``on_tick`` hook), where no worker holds any engine; the race
lint (``analysis/concurrency.py``) certifies that schedule.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import numpy as np

from repro.checkpoint.checkpointer import _digest, resize_axis, tree_paths
from repro.models import transformer as T
from repro.runtime.serving import Request, ServingEngine

# Default transfer-cost rate: Watt·s charged per MiB of snapshot moved
# between destinations (interconnect + host staging). Deliberately modeled,
# like every other rate on the ledger; benchmarks may override it.
DEFAULT_TRANSFER_WS_PER_MIB = 0.5


class MigrationError(RuntimeError):
    """A migration that cannot proceed — deterministic refusal, raised
    before either engine's state is modified."""


@dataclass
class SlotSnapshot:
    """Host-side, mesh-agnostic image of one live slot.

    ``leaves`` mirrors the decode-state structure minus ``pos`` (numpy,
    batch axis dropped); ``manifest``/``digest`` follow the checkpoint
    manifest convention (flat escaped leaf paths -> shape/dtype, sha256
    digest) so integrity is checked at restore; ``cap`` is the effective
    length cap of the ADMITTING engine, preserved across re-migration.
    """

    request: Request
    cursor: int
    pos: int
    cap: int
    source: str  # engine name the snapshot was taken from
    source_max_len: int
    leaves: dict = field(repr=False)
    manifest: dict = field(repr=False)
    digest: str = ""
    nbytes: int = 0


def _leaf_manifest(leaves: dict) -> tuple[dict, int]:
    manifest: dict[str, Any] = {}
    nbytes = 0
    for path, arr in tree_paths(leaves):
        manifest[path] = {"shape": list(arr.shape), "dtype": str(arr.dtype)}
        nbytes += arr.nbytes
    return manifest, nbytes


def _session(engine: ServingEngine) -> tuple[str, dict]:
    if engine._stream is not None:
        return "stream", engine._stream
    if engine._wave is not None:
        return "wave", engine._wave
    raise MigrationError(
        f"engine {engine.name!r} has no open session to migrate through")


def free_slots(engine: ServingEngine) -> list[int]:
    """Slot indices of the open session a snapshot could restore into
    ([] when no session is open)."""
    if engine._stream is not None:
        return [i for i, r in enumerate(engine._stream["slot_req"])
                if r is None]
    if engine._wave is not None:
        w = engine._wave
        # a wave session can grow up to the engine's slot count; inactive
        # wave members keep their slot (their state rows are dead but the
        # wave never refills them — the wave semantics)
        return list(range(len(w["reqs"]), engine.slots))
    return []


def _cache_len(tree: Any, axis: int) -> int:
    return jax.tree.leaves(tree)[0].shape[axis]


def snapshot_slot(engine: ServingEngine, slot: int) -> SlotSnapshot:
    """Pure host-side snapshot of occupied ``slot`` in ``engine``'s open
    session. Read-only on the engine: pair with :func:`detach_slot` (or use
    :func:`migrate`) to actually move the request."""
    kind, s = _session(engine)
    if engine.power_state != "awake":
        # unreachable through the state machine (sleep/floor require
        # idleness), but state surgery deserves a belt
        raise MigrationError(
            f"source {engine.name!r} is {engine.power_state}; only an "
            f"awake engine's decode state is coherent to snapshot")
    if kind == "stream":
        reqs, cursors, caps = s["slot_req"], s["cursors"], s["cap"]
    else:
        reqs, cursors, caps = s["reqs"], s["cursors"], s["cap"]
        if slot < len(reqs) and not s["active"][slot]:
            raise MigrationError(
                f"slot {slot} of {engine.name!r} already finished its wave")
    if slot < 0 or slot >= len(reqs) or reqs[slot] is None:
        raise MigrationError(
            f"slot {slot} of {engine.name!r} holds no admitted request")
    leaves, pos = T.extract_decode_slot(engine.cfg, s["state"], slot)
    manifest, nbytes = _leaf_manifest(leaves)
    return SlotSnapshot(
        request=reqs[slot], cursor=cursors[slot], pos=pos, cap=caps[slot],
        source=engine.name, source_max_len=engine.max_len,
        leaves=leaves, manifest=manifest, digest=_digest(manifest),
        nbytes=nbytes)


def detach_slot(engine: ServingEngine, slot: int) -> Request:
    """Release ``slot`` on the source after its snapshot restored elsewhere:
    the slot frees (a stream slot re-admits from the queue next step), the
    request leaves ``engine.active`` and ``migrations_out`` ticks. No token
    is un-billed — everything decoded here was genuinely served here."""
    kind, s = _session(engine)
    if kind == "stream":
        req = s["slot_req"][slot]
        if req is None:
            raise MigrationError(f"slot {slot} of {engine.name!r} is free")
        s["slot_req"][slot] = None
    else:
        if slot >= len(s["reqs"]) or not s["active"][slot]:
            raise MigrationError(f"slot {slot} of {engine.name!r} is free")
        req = s["reqs"][slot]
        s["active"][slot] = False
    engine.active.remove(req)
    engine.stats.migrations_out += 1
    return req


def _check_geometry(engine: ServingEngine, snap: SlotSnapshot,
                    state: dict) -> None:
    """Deterministic refusals, all raised before any state is written."""
    cfg = engine.cfg
    req = snap.request
    if _digest(snap.manifest) != snap.digest:
        raise MigrationError("snapshot manifest digest mismatch")
    cache_keys = T.decode_state_cache_keys(cfg)
    for key in cache_keys:
        if key not in snap.leaves:
            raise MigrationError(
                f"snapshot is missing state key {key!r} — source and "
                f"target disagree on the model family")
        src_len = _cache_len(snap.leaves[key], 1)  # batch axis dropped
        dst_len = _cache_len(state[key], 2)  # (layers, batch, len, ...)
        if cfg.sliding_window and src_len != dst_len:
            # a ring buffer's occupancy layout is a function of its length
            # (slot = pos % length): resizing would scramble the ring
            raise MigrationError(
                f"sliding-window ring length differs ({src_len} vs "
                f"{dst_len}); refusing to rephase the ring")
        # rows the request can still address: its carried cap bounds every
        # future position, and prompt+max_new_tokens bounds the request's
        # own footprint — whichever is tighter
        needed = min(snap.cap, len(req.prompt) + req.max_new_tokens)
        if dst_len < needed:
            raise MigrationError(
                f"target cache ({dst_len} rows) cannot hold the "
                f"{needed} rows request {req.rid} can still address")


def restore_slot(engine: ServingEngine, snap: SlotSnapshot, *,
                 now: Optional[float] = None,
                 transfer_ws_per_mib: float = DEFAULT_TRANSFER_WS_PER_MIB
                 ) -> int:
    """Reshape ``snap`` onto ``engine``'s geometry and masked-write it into
    a free slot of the open session; returns the slot index.

    Power guard (the sleep→migrate→drain path): a non-awake target without
    a clock refuses outright; with a clock the wake is initiated first
    (wake-charged — ``stats.wakes`` ticks and the driver bills the waking
    interval's full static draw), and the restore still refuses until the
    wake latency has elapsed, so the caller retries on a later tick.
    Either way the refusal is deterministic and the snapshot unconsumed.

    Post-migration tokens bill under the TARGET's placement epoch captured
    here; the transfer itself bills ``nbytes x transfer_ws_per_mib`` to the
    target's ``migration_ws`` ledger line.
    """
    if engine.power_state != "awake":
        if now is None:
            raise MigrationError(
                f"target {engine.name!r} is {engine.power_state} and no "
                f"clock was given to wake-charge it")
        engine.wake(now)
        if not engine.check_awake(now):
            raise MigrationError(
                f"target {engine.name!r} is waking until "
                f"t={engine._awake_at:.3f}; retry after the wake latency")
    kind, s = _session(engine)
    free = free_slots(engine)
    if not free:
        raise MigrationError(f"target {engine.name!r} has no free slot")
    slot = free[0]
    _check_geometry(engine, snap, s["state"])

    leaves = dict(snap.leaves)
    for key in T.decode_state_cache_keys(engine.cfg):
        dst_len = _cache_len(s["state"][key], 2)
        leaves[key] = jax.tree.map(
            lambda v: resize_axis(np.asarray(v), 1, dst_len), leaves[key])
    s["state"] = T.restore_decode_slot(engine.cfg, s["state"], slot,
                                       leaves, snap.pos)
    req = snap.request
    if kind == "stream":
        s["slot_req"][slot] = req
        s["cursors"][slot] = snap.cursor
        s["epoch"][slot] = dict(engine.placements)
        s["cap"][slot] = snap.cap
    else:
        s["reqs"].append(req)
        s["cursors"].append(snap.cursor)
        s["active"].append(True)
        s["epoch"].append(dict(engine.placements))
        s["cap"].append(snap.cap)
    req.served_by = engine.name
    billed = engine.placements.get("decode") or engine.placements.get(
        "prefill")
    req.destination = billed.destination if billed else None
    engine.active.append(req)
    engine.stats.migrations_in += 1
    engine.stats.migration_ws += snap.nbytes / (1 << 20) * transfer_ws_per_mib
    return slot


def migrate(source: ServingEngine, target: ServingEngine, slot: int, *,
            now: Optional[float] = None,
            transfer_ws_per_mib: float = DEFAULT_TRANSFER_WS_PER_MIB) -> int:
    """The transactional move: snapshot ``slot`` off ``source``, restore it
    into ``target``, and only then detach the source slot — a refusal at
    restore leaves the source exactly as it was. Returns the target slot."""
    if source is target:
        raise MigrationError("source and target are the same engine")
    snap = snapshot_slot(source, slot)
    dst = restore_slot(target, snap, now=now,
                       transfer_ws_per_mib=transfer_ws_per_mib)
    detach_slot(source, slot)
    return dst
