"""Fleet router: energy-aware serving across mixed offload destinations.

The PR 2–4 control loop (observe → sweep → narrow → reconfigure) adapts one
:class:`~repro.runtime.serving.ServingEngine`. The paper's end goal is a
*mixed offloading destination environment* (arXiv:2011.12431: GPU + FPGA +
many-core CPU side by side, with arXiv:2110.11520 measuring the Watt·s
consequences): many engines, each pinned to a different destination, with
live traffic routed to whichever destination serves each request cheapest.
:class:`FleetRouter` is that layer:

* **admission routing** — every submitted :class:`Request` is admitted to
  the engine whose current :class:`Placement` minimizes the request's
  *marginal modeled Watt·s* (prompt tokens at the engine's prefill rate +
  generated tokens at its decode rate), subject to the request's ``slo_s``
  (engines whose modeled queue wait + completion latency blow the SLO drop
  out of the candidate set). The policy is pluggable: ``"energy"`` (the
  paper's objective), ``"latency"`` (fastest modeled completion), and
  ``"round_robin"`` (the homogeneous-fleet baseline the benchmarks compare
  against).
* **fleet ledger** — per-engine :class:`EngineStats` aggregate by plain
  field-wise summation into one fleet-wide ledger (Watt·s, occupancy,
  SLO-at-risk): the fleet ledger *is* the sum of the engine ledgers, and
  tests pin that invariant.
* **one shared sweep** — :meth:`plan` observes the *union* traffic mix
  across engines and runs a single ``search_fleet`` sweep over
  (kind × occupancy-bucket) cells × every fleet destination through the
  shared (disk-persisted) :class:`~repro.core.evaluator.EvalEngine` cache,
  then narrows **per engine** on that engine's own destination cells — so
  N engines re-plan on one sweep's measurements and a repeat re-plan
  performs zero new measurements. Destinations differ in *silicon*, not
  just mesh size (:mod:`repro.configs.destinations` pairs each mesh with
  its own power model), so the narrowing has real energy spreads to work
  with.
* **drain/rebalance** — a destination whose swept operating points are
  dominated on every kind's fleet frontier has no reason to receive
  traffic;
  :meth:`rebalance` migrates its *queued (never admitted)* requests to
  surviving engines through the normal routing policy. Admitted requests
  are never moved, so no token is ever billed twice.
* **energy-proportional autoscaling** — every engine carries sleep/wake +
  DVFS-floor power states whose static watts come from its destination's
  ``TpuPowerModel`` idle floor (``configs/destinations.py``), charged to
  the fleet ledger (``EngineStats.idle_ws``) for every second the engine
  is not stepping. :meth:`scale_to` (and :meth:`plan` with
  ``autoscale=True`` and a clock) packs the observed arrival rate into the
  cheapest awake set by amortized Watt·s/token
  (``core/pareto.py:provision_awake_set``), wakes what demand needs and
  spins the rest down; wake latency is charged against request SLOs in
  routing (``eta_s`` adds the wake penalty), and a sleeping engine never
  admits or bills a token. ``benchmarks/traffic_bench.py`` drives this
  under a diurnal open-loop workload (``workload/``): the autoscaled
  fleet must beat always-on on Watt·s/1k-tokens at zero additional SLO
  violations.

Engines run their real decode loops independently; :meth:`run` drives them
sequentially, which keeps fleet outputs token-identical to running each
engine alone on its assigned requests (the ledger integrates *modeled*
time/energy, so serving order does not change any reported number).

See ``docs/ARCHITECTURE.md`` for where the router sits in the
search/serving/telemetry data flow.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Sequence

from repro.configs import ShapeSpec
from repro.configs.destinations import DestinationSpec
from repro.core.cache_store import PersistentEvalCache
from repro.core.device_select import Destination, SelectionReport, \
    select_destination
from repro.core.evaluator import EvalEngine, VectorizedExecutor
from repro.core.fitness import Measurement, UserRequirement
from repro.core.ga import GAConfig
from repro.core.offload_search import CellSpec, FleetResult, search_fleet
from repro.core.pareto import CapacityPoint, ParetoPoint, fleet_frontier, \
    provision_awake_set, select_operating_point
from repro.runtime.placement import DEFAULT_CATALOG, TrafficMix, \
    narrowing_requirement, occupancy_bucket, scale_shape, static_placements
from repro.runtime.serving import EngineStats, Placement, Request, \
    ServingEngine

POLICIES = ("energy", "latency", "round_robin")

_INFEASIBLE = Measurement(time_s=0.0, energy_ws=0.0, feasible=False)


@dataclass
class EngineBinding:
    """One fleet member: a serving engine pinned to a catalog destination."""

    name: str
    dest: DestinationSpec
    engine: ServingEngine
    order: int  # catalog position: the deterministic tie-break


@dataclass
class RouterPlanReport:
    """Introspection record of one shared observe→sweep→narrow pass."""

    mix: TrafficMix
    fleet: Optional[FleetResult]
    # engine name -> kind -> adopted placement (only engines that changed)
    placements: dict[str, dict[str, Placement]] = field(default_factory=dict)
    # kind -> staged §3.3 preferred destination over the whole fleet
    preferred: dict[str, str] = field(default_factory=dict)
    selections: dict[str, SelectionReport] = field(default_factory=dict)
    # destinations dominated on EVERY swept kind's fleet frontier
    dominated: list[str] = field(default_factory=list)
    new_measurements: int = 0
    # autoscaling verdict of this pass (empty when autoscale off / no clock)
    power_states: dict[str, str] = field(default_factory=dict)
    demand_tps: Optional[float] = None


class FleetRouter:
    """Owns N serving engines on mixed destinations and routes live traffic.

    All engines share one model (``cfg``/``params`` — what actually decodes
    locally) and one ``slots``/``max_len`` geometry; they differ in the
    *destination* their placements are priced on. ``destinations`` may
    repeat a spec (a homogeneous scale-out fleet): engines are then named
    ``"<dest>:<i>"`` while the shared sweep still plans the destination
    once.
    """

    def __init__(
        self,
        cfg,
        params,
        destinations: Sequence[DestinationSpec],
        *,
        arch: str,
        policy: str = "energy",
        slots: int = 4,
        max_len: int = 64,
        scheduler: str = "stream",
        overflow: str = "reject",
        cache_path: Optional[str] = "results/eval_cache.jsonl",
        cache_compact: bool = True,
        eval_engine: Optional[EvalEngine] = None,
        ga_config: Optional[GAConfig] = None,
        requirement: Optional[UserRequirement] = None,
        require_energy_improvement: bool = True,
        catalog: Optional[dict[str, ShapeSpec]] = None,
        min_kind_weight: float = 0.02,
        prefer: str = "energy",
        autoscale: bool = False,
        min_awake: int = 1,
        headroom: float = 1.25,
        sleep_after_s: float = 0.0,
        saturation_factor: float = 4.0,
    ) -> None:
        if not destinations:
            raise ValueError("need at least one destination")
        if policy not in POLICIES:
            raise ValueError(f"unknown routing policy {policy!r}; "
                             f"one of {POLICIES}")
        self.arch = arch
        self.policy = policy
        self.catalog = dict(catalog or DEFAULT_CATALOG)
        self.requirement = requirement
        self.require_energy_improvement = require_energy_improvement
        self.min_kind_weight = min_kind_weight
        self.prefer = prefer
        self.autoscale = autoscale
        self.min_awake = max(int(min_awake), 1)
        self.headroom = headroom
        self.sleep_after_s = sleep_after_s
        self.saturation_factor = saturation_factor
        self.ga_config = ga_config or GAConfig(population=10, generations=8)
        if eval_engine is None:
            if cache_path:
                eval_engine = EvalEngine(
                    executor=VectorizedExecutor(),
                    cache=PersistentEvalCache(cache_path,
                                              compact=cache_compact))
            else:
                eval_engine = EvalEngine(executor=VectorizedExecutor())
        self.eval_engine = eval_engine

        counts: dict[str, int] = {}
        for d in destinations:
            counts[d.name] = counts.get(d.name, 0) + 1
        seen: dict[str, int] = {}
        self._bindings: list[EngineBinding] = []
        for i, d in enumerate(destinations):
            if counts[d.name] > 1:
                name = f"{d.name}:{seen.get(d.name, 0)}"
                seen[d.name] = seen.get(d.name, 0) + 1
            else:
                name = d.name
            engine = ServingEngine(cfg, params, slots=slots, max_len=max_len,
                                   overflow=overflow, scheduler=scheduler,
                                   name=name)
            engine.reconfigure(static_placements(
                arch, d.mesh_shape, catalog=self.catalog, power=d.power,
                destination=d.name))
            engine.set_power(idle_watts=d.idle_watts,
                             floor_frac=d.floor_frac,
                             sleep_frac=d.sleep_frac,
                             wake_s=d.wake_s,
                             floor_wake_s=d.floor_wake_s)
            self._bindings.append(EngineBinding(name, d, engine, i))
        # unique destinations in first-appearance order: what one shared
        # sweep plans over (a homogeneous fleet plans its destination once)
        self.destinations: list[DestinationSpec] = []
        for d in destinations:
            if all(x.name != d.name for x in self.destinations):
                self.destinations.append(d)

        self.assignments: dict[int, str] = {}  # rid -> engine name
        self.rejected: list[Request] = []
        self.history: list[RouterPlanReport] = []
        self._rr = 0
        self._last: dict[str, EngineStats] = {
            b.name: b.engine.stats.snapshot() for b in self._bindings}
        self._last_observe_t: Optional[float] = None
        self._idle_since: dict[str, float] = {}

    @classmethod
    def provisioned(
        cls,
        cfg,
        params,
        counts: dict[str, int],
        *,
        catalog: Optional[dict[str, DestinationSpec]] = None,
        **kwargs,
    ) -> "FleetRouter":
        """Build a router from a provisioning plan's destination multiset.

        ``counts`` maps destination-type names to instance counts — exactly
        what :class:`~repro.provision.planner.ProvisionResult` recommends
        (``result.counts``). ``catalog`` resolves names to specs (default:
        the built-in destination catalog); remaining keyword arguments pass
        through to the constructor unchanged. Types appear in catalog
        order, so the engine naming (``"<dest>:<i>"``) is deterministic
        for a given plan.
        """
        from repro.configs.destinations import DESTINATIONS
        table = dict(catalog or DESTINATIONS)
        unknown = set(counts) - set(table)
        if unknown:
            raise ValueError(
                f"provisioned counts name unknown destinations "
                f"{sorted(unknown)}; catalog has {sorted(table)}")
        destinations: list[DestinationSpec] = []
        for name, spec in table.items():
            destinations.extend([spec] * max(int(counts.get(name, 0)), 0))
        if not destinations:
            raise ValueError("provisioned counts expand to an empty fleet")
        return cls(cfg, params, destinations, **kwargs)

    # -- fleet surface -------------------------------------------------
    @property
    def bindings(self) -> list[EngineBinding]:
        return list(self._bindings)

    @property
    def engines(self) -> dict[str, ServingEngine]:
        return {b.name: b.engine for b in self._bindings}

    def fleet_stats(self) -> EngineStats:
        """The fleet-wide ledger: the field-wise sum of every engine's
        :class:`EngineStats` (derived metrics like ``occupancy`` then come
        out traffic-weighted for free)."""
        total = EngineStats()
        for b in self._bindings:
            for f in EngineStats.__dataclass_fields__:
                setattr(total, f, getattr(total, f)
                        + getattr(b.engine.stats, f))
        return total

    def per_engine_stats(self) -> dict[str, EngineStats]:
        return {b.name: b.engine.stats.snapshot() for b in self._bindings}

    # -- routing -------------------------------------------------------
    def marginal_energy_ws(self, engine: ServingEngine, req: Request
                           ) -> float:
        """Modeled Watt·s this request would add to ``engine``'s ledger
        under its current placements: prompt tokens at the prefill rate plus
        generated tokens at the decode rate (the step consuming the last
        prompt token bills as prefill and already emits the first output
        token, hence ``max_new_tokens - 1`` decode tokens)."""
        return (len(req.prompt) * engine.token_energy_ws("prefill")
                + max(req.max_new_tokens - 1, 0)
                * engine.token_energy_ws("decode"))

    def eta_s(self, binding: EngineBinding, req: Request,
              now: Optional[float] = None) -> float:
        """Modeled completion latency on this engine: queued backlog spread
        over its slots, plus the request's own placement-modeled latency.
        With a clock, a spun-down engine's remaining wake latency joins the
        estimate — waking a big pod can blow a tight SLO all by itself."""
        eng = binding.engine
        wait = sum(eng.modeled_latency_s(q) for q in eng.queue) \
            / max(eng.slots, 1)
        wake = eng.wake_penalty_s(now) if now is not None else 0.0
        return wake + wait + eng.modeled_latency_s(req)

    def _awake_pool(self, pool: Sequence[EngineBinding],
                    now: Optional[float]) -> Sequence[EngineBinding]:
        """Routing candidates under power states: asleep engines never admit.
        If the whole pool is dark, the cheapest-to-wake member is woken on
        the spot (the fleet never refuses traffic just because it scaled to
        zero); its wake latency then shows up in ``eta_s``."""
        if now is None:
            return pool
        for b in pool:
            b.engine.check_awake(now)
        awake = [b for b in pool if b.engine.power_state != "asleep"]
        if awake:
            return awake
        b = min(pool, key=lambda x: (x.dest.wake_s, x.order))
        b.engine.wake(now)
        self._idle_since.pop(b.name, None)
        return [b]

    def _route(self, req: Request, pool: Sequence[EngineBinding],
               now: Optional[float] = None) -> EngineBinding:
        if self.policy == "round_robin":
            b = pool[self._rr % len(pool)]
            self._rr += 1
            return b
        pool = self._awake_pool(pool, now)
        if req.slo_s is not None:
            feasible = [b for b in pool
                        if self.eta_s(b, req, now) <= req.slo_s]
            if feasible:
                pool = feasible
            else:
                # no engine can hold the SLO: least-late wins (the request
                # is then counted slo_at_risk at admission)
                return min(pool, key=lambda b: (self.eta_s(b, req, now),
                                                b.order))
        if self.policy == "latency":
            return min(pool, key=lambda b: (self.eta_s(b, req, now), b.order))
        return min(pool, key=lambda b: (self.marginal_energy_ws(b.engine, req),
                                        self.eta_s(b, req, now), b.order))

    def route(self, req: Request, now: Optional[float] = None) -> str:
        """The engine the current policy would admit ``req`` to (pure: no
        state changes except the round-robin cursor on actual submit)."""
        if self.policy == "round_robin":
            return self._bindings[self._rr % len(self._bindings)].name
        return self._route(req, self._bindings, now).name

    def submit(self, req: Request, now: Optional[float] = None) -> bool:
        """Route and submit; False when the chosen engine rejects (empty
        prompt, or the overflow policy refusing an oversized one). With a
        clock, power states participate: asleep engines are skipped (woken
        only if the whole fleet is dark) and a floor-state target is woken
        so the admission actually decodes."""
        binding = self._route(req, self._bindings, now)
        if now is not None and binding.engine.power_state != "awake":
            binding.engine.wake(now)
            self._idle_since.pop(binding.name, None)
        ok = binding.engine.submit(req)
        if ok:
            self.assignments[req.rid] = binding.name
        else:
            self.rejected.append(req)
        return ok

    def run(self, max_waves: int = 64,
            max_steps: Optional[int] = None, *,
            concurrent: bool = False,
            max_workers: Optional[int] = None,
            dwell_s: float = 0.0,
            on_tick=None,
            rebalance_every: int = 0) -> list[Request]:
        """Drain every engine's queue; returns finished requests (engine
        order, completion order within an engine). Engines decode
        independently, so outputs are token-identical to running each engine
        alone on its assigned requests, and the modeled ledger is
        independent of serving order.

        ``concurrent=True`` steps the engines on a thread pool in lockstep
        ticks (:class:`~repro.runtime.executor.FleetExecutor`) —
        token-identical and ledger-identical to the sequential drain (the
        per-engine step schedules are unchanged; only the cross-engine
        interleaving differs, which no engine can observe), pinned by
        regression test. ``dwell_s`` adds an emulated per-step device
        round-trip the concurrent drain overlaps across engines.

        ``on_tick`` (concurrent only) runs on the coordinator thread after
        every tick barrier — the single moment no worker holds any engine,
        which is where mid-flight migrations are safe; ``rebalance_every=k``
        installs the canonical hook: every k ticks, escalate
        :meth:`rebalance` to live load-shedding off saturated engines."""
        if concurrent:
            from repro.runtime.executor import FleetExecutor
            if rebalance_every > 0:
                user_tick = on_tick

                def on_tick(tick, _user=user_tick):  # noqa: F811
                    if tick % rebalance_every == 0:
                        self.rebalance(live=True, include_saturated=True)
                    if _user is not None:
                        _user(tick)
            ex = FleetExecutor(self._bindings, max_workers=max_workers,
                               dwell_s=dwell_s, on_tick=on_tick)
            return ex.run(max_waves=max_waves, max_steps=max_steps)
        done: list[Request] = []
        for b in self._bindings:
            done.extend(b.engine.run(max_waves=max_waves,
                                     max_steps=max_steps))
        return done

    # -- observe (union traffic mix) -----------------------------------
    def observe(self, now: Optional[float] = None) -> TrafficMix:
        """Union traffic mix across all engines since the last observation
        (consumes the window, like the per-engine controller's). With a
        clock, the mix also carries the window's wall span so
        ``TrafficMix.tokens_per_s`` yields the observed arrival rate —
        what autoscaling provisions against."""
        window: Optional[float] = None
        if now is not None:
            if self._last_observe_t is not None:
                window = max(now - self._last_observe_t, 0.0)
            self._last_observe_t = now
        prefill = decode = slot_steps = active = 0
        for b in self._bindings:
            cur, last = b.engine.stats, self._last[b.name]
            prefill += cur.prefill_tokens - last.prefill_tokens
            decode += cur.decode_tokens - last.decode_tokens
            slot_steps += cur.slot_steps - last.slot_steps
            active += cur.active_slot_steps - last.active_slot_steps
            self._last[b.name] = cur.snapshot()
        total = prefill + decode
        weights = (("prefill", prefill / total if total else 0.0),
                   ("decode", decode / total if total else 0.0))
        occ = active / slot_steps if slot_steps else 0.0
        budgets = [s for s in (b.engine.slo_time_per_step_s()
                               for b in self._bindings) if s is not None]
        return TrafficMix(kind_weights=weights, occupancy=occ,
                          occupancy_bucket=occupancy_bucket(occ),
                          tokens=total,
                          slo_time_per_step_s=min(budgets) if budgets
                          else None,
                          window_s=window)

    # -- energy-proportional autoscaling -------------------------------
    def engine_capacity_tps(self, binding: EngineBinding) -> float:
        """Sustainable token throughput of one engine under its current
        placements: slots over the slowest per-token step time (a full
        engine emits one token per slot per step)."""
        rates = [p.time_per_token_s for p in binding.engine.placements.values()
                 if p.time_per_token_s > 0.0]
        if not rates:
            return 0.0
        return binding.engine.slots / max(rates)

    def capacity_points(self) -> list[CapacityPoint]:
        """The fleet's provisioning economics, one point per engine (an
        engine's marginal rate is its most expensive kind — conservative)."""
        return [CapacityPoint(
            name=b.name,
            energy_per_token_ws=max(
                (p.energy_per_token_ws
                 for p in b.engine.placements.values()), default=0.0),
            static_watts=b.dest.idle_watts,
            capacity_tps=self.engine_capacity_tps(b),
            order=b.order) for b in self._bindings]

    def scale_to(self, demand_tps: float, now: float) -> dict[str, str]:
        """Spin the fleet to the cheapest awake set covering ``demand_tps``
        tokens/s (x ``headroom``): engines in the provisioned set wake, the
        rest drop to the DVFS floor once idle and deep-sleep after
        ``sleep_after_s`` continuously idle seconds. An engine with queued
        or in-flight work is never forced down — it drains first and spins
        down on a later tick. Returns {engine name: power state}."""
        for b in self._bindings:
            b.engine.check_awake(now)
        target = set(provision_awake_set(
            self.capacity_points(), demand_tps,
            min_awake=self.min_awake, headroom=self.headroom))
        states: dict[str, str] = {}
        for b in self._bindings:
            eng = b.engine
            if b.name in target:
                self._idle_since.pop(b.name, None)
                if eng.power_state != "awake":
                    eng.wake(now)
            elif eng.idle:
                if eng.power_state == "awake":
                    eng.to_floor()
                    self._idle_since.setdefault(b.name, now)
                if (eng.power_state == "floor"
                        and now - self._idle_since.setdefault(b.name, now)
                        >= self.sleep_after_s):
                    eng.sleep()
            states[b.name] = eng.power_state
        return states

    def power_states(self) -> dict[str, str]:
        return {b.name: b.engine.power_state for b in self._bindings}

    # -- one shared sweep, narrowed per engine -------------------------
    def plan(self, now: Optional[float] = None) -> RouterPlanReport:
        """One shared observe → sweep → narrow → reconfigure pass for the
        whole fleet: a single ``search_fleet`` call over the union mix's
        cells on every destination, then per-engine narrowing on that
        engine's own destination cells. Re-planning the same traffic
        through the persisted cache performs zero new measurements.

        With ``autoscale=True`` and a clock, the pass also spins
        destinations down/up against the window's observed token arrival
        rate (:meth:`scale_to`) — before the early-out, so a trough window
        with no traffic still scales the fleet down."""
        mix = self.observe(now)
        report = RouterPlanReport(mix=mix, fleet=None)
        if self.autoscale and now is not None \
                and mix.tokens_per_s is not None:
            report.demand_tps = mix.tokens_per_s
            report.power_states = self.scale_to(mix.tokens_per_s, now)
        kinds = [k for k in self.catalog
                 if mix.weight(k) > self.min_kind_weight]
        if not kinds:
            self.history.append(report)
            return report

        cells: dict[tuple[str, str], CellSpec] = {}
        for kind in kinds:
            shape = scale_shape(self.catalog[kind], mix.occupancy_bucket)
            for d in self.destinations:
                cells[(kind, d.name)] = CellSpec.create(
                    self.arch, shape, d.mesh_shape, power=d.power)
        fleet = search_fleet(list(cells.values()), ga_config=self.ga_config,
                             engine=self.eval_engine, cell_workers=1)
        report.fleet = fleet
        report.new_measurements = fleet.evaluations
        by_cell = fleet.by_cell()

        # fleet-frontier dominance + staged preferred destination, per kind
        # (cross-kind dominance is meaningless: prefill and decode steps
        # live on different time/energy scales, so a destination is drained
        # only when EVERY kind's frontier rejects it). Membership is tested
        # by each destination's OWN cell key: two destinations on identical
        # silicon share a cell label by design and must share frontier fate
        # — attributing the shared cell to just one of them would falsely
        # drain the other.
        dominated = {d.name for d in self.destinations}
        for kind in kinds:
            kind_results = [by_cell[cells[(kind, d.name)].key]
                            for d in self.destinations]
            kfront = fleet_frontier(cr.search.frontier
                                    for cr in kind_results)
            kfront_cells = {p.cell for p in kfront}
            dominated &= {d.name for d in self.destinations
                          if cells[(kind, d.name)].key not in kfront_cells}
            dest_points = {d.name: [p for p in kfront
                                    if p.cell == cells[(kind, d.name)].key]
                           for d in self.destinations}
            self._stage_preferred(kind, dest_points, mix, report)
        if len(dominated) < len(self.destinations):
            report.dominated = [d.name for d in self.destinations
                                if d.name in dominated]

        for b in self._bindings:
            adopted: dict[str, Placement] = {}
            for kind in kinds:
                cr = by_cell[cells[(kind, b.dest.name)].key]
                tokens = max(cr.spec.shape.tokens(), 1)
                req = narrowing_requirement(
                    base=self.requirement,
                    require_energy_improvement=self.require_energy_improvement,
                    baseline_energy_ws=cr.search.baseline.energy_ws,
                    live=b.engine.placements.get(kind),
                    ref_tokens=tokens,
                    slo_time_per_step_s=mix.slo_time_per_step_s)
                pt = select_operating_point(cr.search.frontier, req,
                                            prefer=self.prefer)
                if pt is None:
                    continue  # keep the engine's current placement
                dec = fleet.decisions_for(pt)
                adopted[kind] = Placement(
                    kind=kind, cell=pt.cell, destination=b.dest.name,
                    decisions=dec, clock=dec.clock,
                    energy_per_token_ws=pt.energy_ws / tokens,
                    time_per_token_s=pt.time_s / tokens, source="adaptive")
            if adopted:
                b.engine.reconfigure({**b.engine.placements, **adopted})
                report.placements[b.name] = adopted
        self.history.append(report)
        return report

    def _stage_preferred(self, kind: str,
                         dest_points: dict[str, list[ParetoPoint]],
                         mix: TrafficMix, report: RouterPlanReport) -> None:
        """Staged §3.3 selection of the fleet-preferred destination for one
        kind: candidates verify cheap-to-expensive (``verify_cost_s`` from
        the catalog) over the already-swept frontier points; a destination
        whose whole frontier is dominated never charges its verify cost."""
        req = narrowing_requirement(
            base=self.requirement, require_energy_improvement=False,
            baseline_energy_ws=0.0, live=None, ref_tokens=max(
                scale_shape(self.catalog[kind],
                            mix.occupancy_bucket).tokens(), 1),
            slo_time_per_step_s=mix.slo_time_per_step_s)

        def make_search(points):
            def _search():
                pt = select_operating_point(points, req, prefer=self.prefer)
                if pt is None:
                    return None, _INFEASIBLE
                return pt, pt.measurement
            return _search

        candidates = [
            Destination(name=d.name, verify_cost_s=d.verify_cost_s,
                        search=make_search(dest_points[d.name]))
            for d in self.destinations if dest_points.get(d.name)
        ]
        if not candidates:
            return
        selection = select_destination(candidates, requirement=req)
        report.selections[kind] = selection
        if selection.chosen is not None:
            report.preferred[kind] = selection.chosen

    # -- drain / rebalance ---------------------------------------------
    def drain(self, name: str,
              survivors: Optional[Sequence[EngineBinding]] = None) -> int:
        """Migrate every *queued* (never admitted) request off engine
        ``name``, re-routing each through the policy over ``survivors``
        (default: every other engine). Admitted requests stay — their
        tokens are already billed to their admission epoch, and moving them
        would bill twice."""
        source = next(b for b in self._bindings if b.name == name)
        pool = list(survivors if survivors is not None
                    else (b for b in self._bindings if b.name != name))
        if not pool:
            return 0
        moved = 0
        while source.engine.queue:
            req = source.engine.queue.popleft()
            target = self._route(req, pool)
            # direct queue hand-off: the request was vetted at its original
            # submit and the fleet shares one max_len, so re-vetting (and
            # re-counting truncation) would distort the fleet ledger
            target.engine.queue.append(req)
            self.assignments[req.rid] = target.name
            moved += 1
        return moved

    def saturated(self) -> list[str]:
        """Engines whose queued backlog exceeds ``saturation_factor`` x
        their slot count — the spike signal live rebalancing sheds from."""
        return [b.name for b in self._bindings
                if len(b.engine.queue)
                > self.saturation_factor * b.engine.slots]

    def migrate_slot(self, source: str, slot: int, target: str,
                     now: Optional[float] = None) -> int:
        """Move ONE admitted (in-flight) request: snapshot ``slot`` off
        engine ``source`` and restore it into a free slot of ``target``
        (:mod:`repro.runtime.migration` — transactional: a refusal leaves
        the source untouched). Tokens decoded after the move bill under the
        target's placement epoch; the transfer bills a separate
        ``migration_ws`` ledger line on the target; no token bills twice.
        Returns the target slot index."""
        from repro.runtime.migration import migrate
        src = next(b for b in self._bindings if b.name == source)
        dst = next(b for b in self._bindings if b.name == target)
        req, _ = self._slot_request(src, slot)
        out = migrate(src.engine, dst.engine, slot, now=now)
        self.assignments[req.rid] = dst.name
        return out

    def _slot_request(self, binding: EngineBinding, slot: int):
        from repro.runtime import migration
        sess_kind, s = migration._session(binding.engine)
        reqs = s["slot_req"] if sess_kind == "stream" else s["reqs"]
        if slot >= len(reqs) or reqs[slot] is None:
            from repro.runtime.migration import MigrationError
            raise MigrationError(
                f"slot {slot} of {binding.name!r} holds no request")
        return reqs[slot], sess_kind

    def _live_shed(self, source: EngineBinding,
                   survivors: Sequence[EngineBinding],
                   now: Optional[float]) -> int:
        """Migrate ``source``'s admitted slots (ascending slot order) onto
        awake survivors with free slots, chosen by the routing policy's
        cost (energy: marginal modeled Watt·s; latency: modeled ETA;
        catalog order breaks ties). Stops at the first slot no survivor
        can take — refusals are deterministic, not silent drops."""
        from repro.runtime import migration
        moved = 0
        try:
            sess_kind, s = migration._session(source.engine)
        except migration.MigrationError:
            return 0
        reqs = s["slot_req"] if sess_kind == "stream" else s["reqs"]
        for slot in range(len(reqs)):
            req = reqs[slot]
            if req is None or (sess_kind == "wave"
                               and not s["active"][slot]):
                continue
            cands = []
            for b in survivors:
                if now is not None:
                    b.engine.check_awake(now)
                if b.engine.power_state != "awake":
                    continue
                if not migration.free_slots(b.engine):
                    continue
                cands.append(b)
            if not cands:
                return moved
            if self.policy == "latency":
                target = min(cands, key=lambda b: (self.eta_s(b, req, now),
                                                   b.order))
            else:
                target = min(cands,
                             key=lambda b: (self.marginal_energy_ws(
                                 b.engine, req), b.order))
            try:
                migration.migrate(source.engine, target.engine, slot,
                                  now=now)
            except migration.MigrationError:
                continue  # geometry refusal: try the next slot
            self.assignments[req.rid] = target.name
            moved += 1
        return moved

    def rebalance(self, dominated: Optional[Sequence[str]] = None, *,
                  live: bool = False, now: Optional[float] = None,
                  include_saturated: Optional[bool] = None
                  ) -> dict[str, int]:
        """Shed load off engines whose destination is dominated on the
        fleet frontier (default: the last plan's verdict) and — when
        ``include_saturated`` (default: follows ``live``) — off engines
        whose queue exceeds the saturation threshold.

        The base move is the PR 5 queue-drain (queued, never-admitted
        requests re-route through the policy). ``live=True`` escalates to
        **mid-flight migration of admitted requests**: occupied slots move
        to awake survivors with free capacity through
        :meth:`migrate_slot`'s billing contract (post-move tokens bill
        under the target's epoch, the transfer bills ``migration_ws``, no
        token twice). Returns {engine name: requests moved} counting both
        kinds."""
        if dominated is None:
            dominated = self.history[-1].dominated if self.history else []
        dominated = set(dominated)
        if include_saturated is None:
            include_saturated = live
        source_names = {b.name for b in self._bindings
                        if b.dest.name in dominated}
        if include_saturated:
            source_names |= set(self.saturated())
        if not source_names:
            return {}
        sources = [b for b in self._bindings if b.name in source_names]
        survivors = [b for b in self._bindings
                     if b.name not in source_names]
        if not survivors:
            return {}  # refusing to drain the whole fleet
        moved: dict[str, int] = {}
        for b in sources:
            n = self.drain(b.name, survivors)
            if live:
                n += self._live_shed(b, survivors, now)
            if n:
                moved[b.name] = n
        return moved
