"""Open-loop, seed-deterministic traffic generation at fleet scale.

The bench scenarios before this module were a handful of hand-built request
lists: the router never saw queueing pressure, and no engine was ever worth
spinning down. This generator produces the load a million-user deployment
actually presents — timestamped request *streams* the fleet consumes at
wall-clock-simulated rates (``workload/driver.py``) — while staying exactly
reproducible: the same :class:`WorkloadSpec` (same seed) emits a
byte-identical trace, pinned by :func:`trace_digest`.

Modeled phenomena (cf. the 33-app power evaluation of arXiv:2110.11520 —
energy conclusions need realistic, reproducible load):

* **arrival processes** — open-loop Poisson (exponential interarrivals) or
  **bursty** (a two-state Markov-modulated Poisson process: quiet base rate
  with seeded burst episodes at a rate multiplier), both modulated by a
  **diurnal cycle**: a sinusoidal rate envelope between ``trough`` and
  ``peak`` multipliers with a configurable period — the load shape that
  makes energy-proportional autoscaling matter (idle watts during the
  trough are pure waste for an always-on fleet).
* **heavy-tailed lengths** — prompt and output lengths are discretized
  log-normals (most requests short, a long tail), clamped to configured
  caps so the stream **never** emits a ``prompt >= max_len`` reject: every
  request fits its engine by construction, with room for at least one
  generated token.
* **SLO classes + multi-tenant mixes** — each :class:`TenantSpec` is one
  tenant class (interactive chat, batch summarization, ...) with its own
  length profile, optional completion SLO and traffic weight; the stream
  interleaves tenants by weighted seeded choice.

Everything uses ``random.Random(seed)`` (pure Python, platform-stable) —
no wall clocks, no numpy RNG state: two calls with one spec are
byte-identical, which the property tests (``tests/test_workload.py``)
exercise through ``tests/_hypothesis_compat.py``.
"""
from __future__ import annotations

import hashlib
import math
import random
from dataclasses import dataclass, field
from typing import Iterator, Optional, Sequence

from repro.runtime.serving import Request

ARRIVALS = ("poisson", "bursty")


@dataclass(frozen=True)
class TenantSpec:
    """One tenant class: a length profile, an SLO class and a mix weight.

    Lengths are log-normal in shape: ``exp(N(log(median), sigma))``,
    discretized and clamped to ``[lo, hi]`` — median-parameterized so specs
    read naturally ("median 12-token prompts, heavy tail to 64")."""

    name: str
    weight: float = 1.0
    prompt_median: int = 12
    prompt_sigma: float = 0.6
    prompt_max: int = 48
    new_tokens_median: int = 6
    new_tokens_sigma: float = 0.5
    new_tokens_max: int = 16
    slo_s: Optional[float] = None  # completion-latency SLO (None = batch)
    eos_id: Optional[int] = None
    vocab: int = 17  # prompt tokens are drawn from [1, vocab]


@dataclass(frozen=True)
class WorkloadSpec:
    """One reproducible open-loop workload.

    ``rate_rps`` is the *mean* arrival rate in requests per (simulated)
    second before diurnal/burst modulation; ``duration_s`` bounds the
    arrival timeline. ``max_len`` is the serving engines' cache length: the
    generator guarantees ``len(prompt) + 1 <= max_len`` for every emitted
    request (no admission rejects, ever) by clamping prompts to
    ``min(tenant.prompt_max, max_len - 1)`` and additionally leaving room
    for the request's own generation budget when ``reserve_output`` is set
    (no ``length_cap`` finishes either)."""

    seed: int = 0
    duration_s: float = 1.0
    rate_rps: float = 100.0
    max_len: int = 48
    arrival: str = "poisson"  # "poisson" | "bursty"
    # diurnal sinusoid: rate(t) = rate_rps * lerp(trough, peak) over period
    diurnal_period_s: float = 0.0  # 0 = flat (no cycle)
    diurnal_trough: float = 1.0  # rate multiplier at the valley
    diurnal_peak: float = 1.0  # rate multiplier at the crest
    # bursty (MMPP) knobs: mean episode lengths + in-burst multiplier
    burst_rate_mult: float = 4.0
    burst_mean_s: float = 0.05
    quiet_mean_s: float = 0.2
    reserve_output: bool = True  # prompts leave room for max_new_tokens too
    tenants: tuple[TenantSpec, ...] = (TenantSpec("default"),)

    def __post_init__(self) -> None:
        if self.arrival not in ARRIVALS:
            raise ValueError(f"unknown arrival process {self.arrival!r}; "
                             f"one of {ARRIVALS}")
        if self.rate_rps <= 0.0 or self.duration_s <= 0.0:
            raise ValueError("rate_rps and duration_s must be positive")
        if self.max_len < 2:
            raise ValueError("max_len must fit a prompt token plus a "
                             "generated one")
        if not self.tenants:
            raise ValueError("need at least one tenant")
        if self.diurnal_period_s > 0.0 and not (
                0.0 <= self.diurnal_trough <= self.diurnal_peak):
            raise ValueError("diurnal multipliers need "
                             "0 <= trough <= peak")


@dataclass(frozen=True)
class TimedRequest:
    """One arrival: when it hits the front door, whose it is, what it asks."""

    at_s: float
    tenant: str
    request: Request = field(compare=False)

    @property
    def rid(self) -> int:
        return self.request.rid

    def tokens(self) -> int:
        """Total token demand this arrival puts on the fleet (prompt +
        generation budget) — what autoscaling sizes capacity against."""
        return len(self.request.prompt) + self.request.max_new_tokens


def diurnal_mult(spec: WorkloadSpec, t: float) -> float:
    """Rate multiplier at time ``t``: a sinusoid from ``diurnal_peak`` (at
    t=0) down to ``diurnal_trough`` and back over ``diurnal_period_s``."""
    if spec.diurnal_period_s <= 0.0:
        return 1.0
    phase = math.cos(2.0 * math.pi * t / spec.diurnal_period_s)
    lo, hi = spec.diurnal_trough, spec.diurnal_peak
    return lo + (hi - lo) * 0.5 * (1.0 + phase)


def _lognormal_int(rng: random.Random, median: int, sigma: float,
                   lo: int, hi: int) -> int:
    """Discretized log-normal with the given median, clamped to [lo, hi]."""
    if hi <= lo:
        return max(lo, 1)
    v = int(round(math.exp(rng.gauss(math.log(max(median, 1)), sigma))))
    return max(lo, min(hi, v))


def _arrival_times(spec: WorkloadSpec, rng: random.Random) -> Iterator[float]:
    """Arrival timestamps on [0, duration): a Poisson process thinned by the
    diurnal envelope, with the bursty variant layering a two-state MMPP
    (quiet/burst) rate multiplier on top.

    Thinning draws candidates at the *maximum* instantaneous rate and keeps
    each with probability rate(t)/rate_max — the standard exact method for
    inhomogeneous Poisson processes, and deterministic under the seeded
    rng."""
    peak_mult = (max(spec.diurnal_peak, 1e-9)
                 if spec.diurnal_period_s > 0.0 else 1.0)
    burst_mult = spec.burst_rate_mult if spec.arrival == "bursty" else 1.0
    rate_max = spec.rate_rps * max(peak_mult, 1e-9) * max(burst_mult, 1.0)

    in_burst = False
    phase_end = 0.0
    t = 0.0
    while True:
        t += rng.expovariate(rate_max)
        if t >= spec.duration_s:
            return
        rate = spec.rate_rps * diurnal_mult(spec, t)
        if spec.arrival == "bursty":
            while t >= phase_end:  # advance the MMPP phase machine to t
                in_burst = not in_burst if phase_end > 0.0 else \
                    rng.random() < spec.burst_mean_s / max(
                        spec.burst_mean_s + spec.quiet_mean_s, 1e-9)
                mean = spec.burst_mean_s if in_burst else spec.quiet_mean_s
                phase_end += rng.expovariate(1.0 / max(mean, 1e-9))
            if in_burst:
                rate *= spec.burst_rate_mult
        if rng.random() < rate / rate_max:
            yield t


def _pick_tenant(spec: WorkloadSpec, rng: random.Random) -> TenantSpec:
    total = sum(t.weight for t in spec.tenants)
    x = rng.random() * total
    for t in spec.tenants:
        x -= t.weight
        if x <= 0.0:
            return t
    return spec.tenants[-1]


def generate(spec: WorkloadSpec, *, rid_base: int = 0) -> list[TimedRequest]:
    """Emit the full arrival trace for ``spec`` — deterministically.

    Each arrival draws its tenant by weight, then its prompt/output lengths
    from the tenant's clamped log-normals. Prompt caps guarantee admission:
    ``len(prompt) < max_len`` always, and with ``reserve_output`` the prompt
    additionally leaves the request's whole generation budget inside
    ``max_len`` (no silent ``length_cap`` finishes)."""
    rng = random.Random(spec.seed)
    out: list[TimedRequest] = []
    for i, t in enumerate(_arrival_times(spec, rng)):
        tenant = _pick_tenant(spec, rng)
        new_max = min(tenant.new_tokens_max, spec.max_len - 1)
        gen = _lognormal_int(rng, tenant.new_tokens_median,
                             tenant.new_tokens_sigma, 1, new_max)
        cap = spec.max_len - 1
        if spec.reserve_output:
            cap = spec.max_len - gen
        cap = min(tenant.prompt_max, cap)
        plen = _lognormal_int(rng, tenant.prompt_median, tenant.prompt_sigma,
                              1, cap)
        prompt = [1 + rng.randrange(tenant.vocab) for _ in range(plen)]
        req = Request(rid=rid_base + i, prompt=prompt, max_new_tokens=gen,
                      eos_id=tenant.eos_id, slo_s=tenant.slo_s)
        out.append(TimedRequest(at_s=t, tenant=tenant.name, request=req))
    return out


def trace_bytes(trace: Sequence[TimedRequest]) -> bytes:
    """Canonical byte serialization of a trace (what determinism means)."""
    lines = []
    for tr in trace:
        r = tr.request
        lines.append("|".join((
            f"{tr.at_s!r}", tr.tenant, str(r.rid),
            ",".join(map(str, r.prompt)), str(r.max_new_tokens),
            repr(r.slo_s), repr(r.eos_id))))
    return "\n".join(lines).encode("utf-8")


def trace_digest(trace: Sequence[TimedRequest]) -> str:
    """SHA-256 of the canonical serialization: equal digests == the same
    trace, byte for byte — the reproducibility handle the property tests
    and ``benchmarks/traffic_bench.py`` pin."""
    return hashlib.sha256(trace_bytes(trace)).hexdigest()


def empirical_rate_rps(trace: Sequence[TimedRequest],
                       duration_s: float) -> float:
    return len(trace) / duration_s if duration_s > 0 else 0.0


def mean_diurnal_mult(spec: WorkloadSpec, n: int = 512) -> float:
    """Time-average of the diurnal envelope (for rate-tolerance tests: the
    empirical arrival rate estimates ``rate_rps`` x this average)."""
    if spec.diurnal_period_s <= 0.0:
        return 1.0
    return sum(diurnal_mult(spec, spec.duration_s * (i + 0.5) / n)
               for i in range(n)) / n
