"""Virtual-clock fleet simulation: open-loop arrivals meet real queueing.

``generate()`` (``workload/generator.py``) emits a timestamped request
trace; this driver replays it against a :class:`FleetRouter` on one
simulated clock, which is what makes the energy-proportional story
measurable at all:

* **real queueing pressure** — requests arrive when the trace says, not
  when an engine happens to be free. An engine mid-step cannot admit; the
  backlog builds, occupancy rises, completion latency (and therefore SLO
  compliance) becomes an *outcome* instead of an input.
* **modeled step durations** — each ``stream_step`` advances an engine's
  clock by the step's modeled duration (``ServingEngine.last_step_s``: the
  max per-token time across its active slots under their admission
  epochs), so heterogeneous destinations genuinely serve at different
  speeds.
* **idle accounting with no double-count** — for exactly the wall-clock
  intervals an engine did NOT step in, the driver charges the engine's
  current power state's static draw to ``EngineStats.idle_ws``
  (``accrue_idle``). Busy steps already carry the idle term inside their
  per-token rates; the union of "stepping" and "accrued idle" intervals
  tiles the simulated timeline exactly once.
* **autoscaling ticks** — at a fixed cadence the driver estimates token
  demand over a sliding arrival window and calls
  :meth:`FleetRouter.scale_to`; wake latencies then delay real admissions
  and show up as SLO violations if the fleet scaled down too eagerly.

Everything is deterministic: the trace is seeded, the event loop breaks
ties in binding order, and the modeled ledger never touches a wall clock —
the same trace against the same fleet reproduces the same
:class:`SimReport` field for field.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Optional, Sequence

from repro.runtime.router import FleetRouter
from repro.runtime.serving import EngineStats
from repro.workload.generator import TimedRequest


@dataclass
class SimReport:
    """Everything one simulated serve produced (ledger fields are deltas
    over the simulation, so a reused router doesn't leak prior traffic)."""

    duration_s: float  # simulated horizon the idle ledger covers
    submitted: int
    completed: int
    rejected: int
    steps: int
    tokens: int  # prefill + decode tokens actually served
    energy_ws: float  # modeled serving energy (per-token rates)
    idle_ws: float  # static draw charged for non-stepping wall time
    slo_total: int  # submitted requests carrying an SLO
    slo_violations: int  # end-to-end completion later than slo_s
    # transfer cost of mid-flight slot migrations (bytes x link rate),
    # billed to receiving engines — on the full bill, so a migration-happy
    # policy cannot look cheap by hiding its moves
    migration_ws: float = 0.0
    migrations: int = 0  # slots moved mid-flight
    finish_s: dict[int, float] = field(default_factory=dict)  # rid -> t
    # (t, {engine: state}) every time an autoscaling tick changed anything
    power_log: list[tuple[float, dict[str, str]]] = field(default_factory=list)
    fleet: EngineStats = field(default_factory=EngineStats)

    @property
    def total_ws(self) -> float:
        """The full bill: serving energy plus static idle energy plus
        migration transfer cost."""
        return self.energy_ws + self.idle_ws + self.migration_ws

    @property
    def ws_per_1k_tokens(self) -> float:
        """The paper-style headline metric, on the FULL bill — an always-on
        fleet pays its idle floors here, which is the entire point."""
        return self.total_ws / self.tokens * 1000.0 if self.tokens else 0.0


def simulate(router: FleetRouter, trace: Sequence[TimedRequest], *,
             horizon_s: Optional[float] = None,
             autoscale_every_s: Optional[float] = None,
             rate_window_s: Optional[float] = None,
             plan_times: Sequence[float] = (),
             rebalance_every_s: Optional[float] = None,
             rebalance_live: bool = False,
             min_step_s: float = 1e-9,
             max_events: int = 2_000_000) -> SimReport:
    """Replay ``trace`` against ``router`` on a virtual clock.

    ``horizon_s`` extends the idle ledger (and autoscaling ticks) to a fixed
    end time even after the last request drains — always-on and autoscaled
    runs must be billed over the SAME wall span to compare fairly.
    ``autoscale_every_s`` enables control ticks: demand is the token sum of
    arrivals in the trailing ``rate_window_s`` (default 4 ticks) divided by
    the window. ``plan_times`` additionally runs full
    ``router.plan(now=t)`` passes at the given times.
    ``rebalance_every_s`` runs ``router.rebalance(include_saturated=True)``
    at a fixed cadence — queue-drain by default, escalated to mid-flight
    migration of admitted slots with ``rebalance_live=True`` (the
    saturation-spike comparison ``benchmarks/migration_bench.py`` gates).
    ``min_step_s`` guards
    the clock against placement-less engines modeling zero-duration steps.
    """
    bindings = router.bindings
    base = {b.name: b.engine.stats.snapshot() for b in bindings}
    pending = deque(sorted(trace, key=lambda tr: (tr.at_s, tr.rid)))
    total_arrivals = len(pending)

    window = rate_window_s if rate_window_s is not None else \
        (4.0 * autoscale_every_s if autoscale_every_s else 1.0)
    arrivals: deque[tuple[float, int]] = deque()  # (t, token demand)
    next_tick = autoscale_every_s if autoscale_every_s else None
    next_reb = rebalance_every_s if rebalance_every_s else None
    plan_q = deque(sorted(plan_times))

    avail = {b.name: 0.0 for b in bindings}  # earliest next step start
    accrued_to = {b.name: 0.0 for b in bindings}  # idle ledger watermark
    finish_s: dict[int, float] = {}
    power_log: list[tuple[float, dict[str, str]]] = []
    last_states = router.power_states()
    submitted = rejected = steps = 0
    now = 0.0

    def next_step_time(b) -> Optional[float]:
        """When this engine could start its next step (None: no work)."""
        if not b.engine.stream_busy():
            return None
        t = max(avail[b.name], now)
        return t + b.engine.wake_penalty_s(t)

    for b in bindings:
        b.engine.stream_open()
    try:
        for _ in range(max_events):
            cands: list[float] = []
            if pending:
                cands.append(pending[0].at_s)
            busy = False
            for b in bindings:
                st = next_step_time(b)
                if st is not None:
                    busy = True
                    cands.append(st)
            has_work = bool(pending) or busy
            if next_tick is not None and (
                    has_work or (horizon_s is not None
                                 and next_tick <= horizon_s)):
                cands.append(next_tick)
            if next_reb is not None and has_work:
                cands.append(next_reb)
            if plan_q:
                cands.append(plan_q[0])
            if not cands:
                break
            now = max(now, min(cands))

            # idle accrual first: it covers time strictly BEFORE `now`,
            # under the power states held during that interval — events at
            # `now` (wakes, floors, steps) must not retroactively reprice it
            for b in bindings:
                dt = now - accrued_to[b.name]
                if dt > 0.0:
                    b.engine.accrue_idle(dt)
                    accrued_to[b.name] = now

            while pending and pending[0].at_s <= now:
                tr = pending.popleft()
                arrivals.append((tr.at_s, tr.tokens()))
                submitted += 1
                if not router.submit(tr.request, now=now):
                    rejected += 1
            while plan_q and plan_q[0] <= now:
                plan_q.popleft()
                router.plan(now=now)
            while next_tick is not None and next_tick <= now:
                cutoff = next_tick - window
                while arrivals and arrivals[0][0] <= cutoff:
                    arrivals.popleft()
                demand = sum(tok for _, tok in arrivals) / window
                if router.autoscale:
                    states = router.scale_to(demand, now)
                    if states != last_states:
                        power_log.append((now, dict(states)))
                        last_states = dict(states)
                next_tick += autoscale_every_s
            while next_reb is not None and next_reb <= now:
                router.rebalance(live=rebalance_live,
                                 include_saturated=True, now=now)
                next_reb += rebalance_every_s

            for b in bindings:
                eng = b.engine
                if not eng.stream_busy() or avail[b.name] > now:
                    continue
                if eng.power_state in ("floor", "asleep"):
                    eng.wake(now)  # defensive: work never waits on standby
                if not eng.check_awake(now):
                    continue
                finished = eng.stream_step()
                if finished is None:
                    continue
                steps += 1
                d = max(eng.last_step_s, min_step_s)
                avail[b.name] = now + d
                accrued_to[b.name] = now + d  # busy interval: billed by token
                for req in finished:
                    finish_s[req.rid] = now + d
        else:
            raise RuntimeError(f"simulation exceeded {max_events} events "
                               "without draining")
    finally:
        for b in bindings:
            b.engine.stream_close()

    end = max([now, horizon_s or 0.0] + list(avail.values()))
    for b in bindings:
        dt = end - accrued_to[b.name]
        if dt > 0.0:
            b.engine.accrue_idle(dt)
            accrued_to[b.name] = end

    fleet = EngineStats()
    for b in bindings:
        cur, b0 = b.engine.stats, base[b.name]
        for f in EngineStats.__dataclass_fields__:
            setattr(fleet, f,
                    getattr(fleet, f) + getattr(cur, f) - getattr(b0, f))

    slo_total = slo_violations = 0
    for tr in trace:
        req = tr.request
        if req.slo_s is None:
            continue
        slo_total += 1
        done_at = finish_s.get(req.rid)
        if done_at is None or done_at - tr.at_s > req.slo_s:
            slo_violations += 1  # unserved SLO traffic counts as violated

    assert submitted == total_arrivals
    return SimReport(duration_s=end, submitted=submitted,
                     completed=len(finish_s), rejected=rejected,
                     steps=steps, tokens=fleet.total_tokens,
                     energy_ws=fleet.energy_ws, idle_ws=fleet.idle_ws,
                     slo_total=slo_total, slo_violations=slo_violations,
                     migration_ws=fleet.migration_ws,
                     migrations=fleet.migrations_in,
                     finish_s=finish_s, power_log=power_log, fleet=fleet)
