"""Open-loop traffic generation + virtual-clock fleet simulation.

``generator`` emits seed-deterministic timestamped request traces
(Poisson/bursty arrivals, diurnal envelopes, heavy-tailed lengths,
multi-tenant SLO classes); ``driver`` replays a trace against a
:class:`~repro.runtime.router.FleetRouter` on a simulated clock with
energy-proportional power-state accounting.
"""
from repro.workload.driver import SimReport, simulate
from repro.workload.forecast import TenantForecast, WorkloadForecast
from repro.workload.generator import (
    ARRIVALS, TenantSpec, TimedRequest, WorkloadSpec, diurnal_mult,
    empirical_rate_rps, generate, mean_diurnal_mult, trace_bytes,
    trace_digest,
)

__all__ = [
    "ARRIVALS", "SimReport", "TenantForecast", "TenantSpec", "TimedRequest",
    "WorkloadForecast", "WorkloadSpec", "diurnal_mult", "empirical_rate_rps",
    "generate", "mean_diurnal_mult", "simulate", "trace_bytes",
    "trace_digest",
]
