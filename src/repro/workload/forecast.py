"""Workload forecasts: the demand summary capacity planning provisions for.

Provisioning (``repro.provision``) decides *which destinations to build*
before any request arrives, so it cannot observe traffic the way the
router's control loop does — it plans against a **forecast**: a compact,
deterministic summary of the traffic a :class:`WorkloadSpec` describes.
:func:`WorkloadForecast.from_spec` generates the spec's seed-deterministic
trace once (``workload/generator.py`` — byte-identical per seed, pinned by
``trace_digest``) and reduces it to exactly the quantities a capacity plan
needs:

* **mean and peak token rates** — the mean sizes the energy bill (what the
  fleet serves second over second); the peak sizes capacity (what the
  built fleet must be able to absorb). Peak is the maximum windowed token
  arrival rate over ``peak_windows`` equal slices of the horizon, so a
  diurnal crest or burst episode shows up instead of averaging away.
* **prefill/decode split** — destinations differ in which kind they serve
  cheaply (``configs/destinations.py``: compute-optimized parts win
  prefill, memory-optimized parts win decode), so the mix weighting is
  what makes heterogeneous builds score differently at all.
* **per-tenant latency profiles** — observed median prompt/output lengths
  plus the spec's completion SLOs: enough to ask "can destination D finish
  this tenant's median request inside its SLO?" without replaying traffic.

Everything derives from the generated trace (not the spec's nominal
parameters), so clamping, diurnal thinning and tenant weighting are already
folded in, and the same spec always produces the identical forecast — the
determinism the provisioning property tests and ``BENCH_provision.json``
byte-identity rest on.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

from repro.workload.generator import (
    TimedRequest, WorkloadSpec, generate, trace_digest,
)


def _median_int(values: Sequence[int]) -> int:
    """Lower median (deterministic, integer-valued) of a non-empty list."""
    ordered = sorted(values)
    return ordered[(len(ordered) - 1) // 2]


@dataclass(frozen=True)
class TenantForecast:
    """One tenant class's planning profile, measured from the trace."""

    name: str
    requests: int
    prompt_median: int  # observed median prompt length (tokens)
    new_tokens_median: int  # observed median generation budget
    slo_s: Optional[float]  # completion SLO (None = batch traffic)


@dataclass(frozen=True)
class WorkloadForecast:
    """The demand summary a provisioning search evaluates fleets against."""

    duration_s: float
    requests: int
    total_tokens: int  # prompt + generation budget over the whole trace
    mean_tps: float  # total_tokens / duration
    peak_tps: float  # max windowed arrival rate (capacity sizing)
    prefill_frac: float  # prompt share of total tokens
    tenants: tuple[TenantForecast, ...]
    trace_digest: str  # the generated trace this forecast summarizes

    @property
    def decode_frac(self) -> float:
        return 1.0 - self.prefill_frac

    def slo_tenants(self) -> tuple[TenantForecast, ...]:
        return tuple(t for t in self.tenants if t.slo_s is not None)

    @staticmethod
    def from_trace(trace: Sequence[TimedRequest], duration_s: float,
                   *, peak_windows: int = 16) -> "WorkloadForecast":
        """Summarize an already-generated trace (``from_spec`` is the
        usual entry; this one serves tests and replayed live traces)."""
        if duration_s <= 0.0:
            raise ValueError("duration_s must be positive")
        windows = max(int(peak_windows), 1)
        win = duration_s / windows
        bucket_tokens = [0] * windows
        prompt_tokens = 0
        total_tokens = 0
        per_tenant: dict[str, list[TimedRequest]] = {}
        for tr in trace:
            tokens = tr.tokens()
            total_tokens += tokens
            prompt_tokens += len(tr.request.prompt)
            idx = min(int(tr.at_s / win), windows - 1)
            bucket_tokens[idx] += tokens
            per_tenant.setdefault(tr.tenant, []).append(tr)
        tenants = tuple(
            TenantForecast(
                name=name,
                requests=len(trs),
                prompt_median=_median_int(
                    [len(t.request.prompt) for t in trs]),
                new_tokens_median=_median_int(
                    [t.request.max_new_tokens for t in trs]),
                slo_s=trs[0].request.slo_s)
            for name, trs in sorted(per_tenant.items()))
        return WorkloadForecast(
            duration_s=duration_s,
            requests=len(trace),
            total_tokens=total_tokens,
            mean_tps=total_tokens / duration_s,
            peak_tps=max(bucket_tokens) / win if trace else 0.0,
            prefill_frac=(prompt_tokens / total_tokens
                          if total_tokens else 0.0),
            tenants=tenants,
            trace_digest=trace_digest(trace))

    @staticmethod
    def from_spec(spec: WorkloadSpec, *,
                  peak_windows: int = 16) -> "WorkloadForecast":
        """Generate ``spec``'s deterministic trace and summarize it."""
        return WorkloadForecast.from_trace(
            generate(spec), spec.duration_s, peak_windows=peak_windows)

    def to_json(self) -> dict:
        return {
            "duration_s": self.duration_s,
            "requests": self.requests,
            "total_tokens": self.total_tokens,
            "mean_tps": self.mean_tps,
            "peak_tps": self.peak_tps,
            "prefill_frac": self.prefill_frac,
            "trace_digest": self.trace_digest,
            "tenants": [
                {"name": t.name, "requests": t.requests,
                 "prompt_median": t.prompt_median,
                 "new_tokens_median": t.new_tokens_median,
                 "slo_s": t.slo_s}
                for t in self.tenants],
        }
