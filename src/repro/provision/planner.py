"""Budgeted fleet provisioning: search which destinations to *build*.

Every layer below this one takes the hardware mix as given: the router
picks which existing engine serves a request, autoscaling picks which
existing engine stays awake. The operator question upstream of both —
the one lumos (SNIPPETS.md 1-3) poses for MPSoCs and ROADMAP item 2 poses
for this fleet — is which destinations to stand up at all, under a power
(and optionally chip-area) budget, before any request arrives. This module
answers it by reusing the existing machinery at one level up:

1. **economics** (:func:`destination_economics`) — one shared
   ``search_fleet`` sweep prices every (kind x destination) cell through
   the per-cell GA and its Pareto frontier, exactly as the router's
   control loop does, through the same (disk-persistable)
   ``PersistentEvalCache`` — so planning tomorrow's build reuses today's
   measurements and a cached re-plan performs **zero** new ones. The
   ``screen.py`` pre-screen drops infeasible cells before measurement
   (dominance pruning stays OFF: a cell dominated on the (time, energy)
   plane can still be the cheapest *per provisioned watt*, which is the
   axis this search optimizes).
2. **evaluation** (:func:`evaluate_fleet`) — a candidate build is a
   :class:`FleetGenome` (multiset of destination counts). Its nameplate
   watts/area debit the :class:`~repro.provision.budget.Budget`; its
   serving cost at the forecast mean rate comes from the PR 6 power-state
   model (``CapacityPoint`` / ``provision_awake_set`` /
   ``allocate_demand``), so the idle floors of over-provisioned engines
   — awake static draw for the provisioned set, sleep-fraction draw for
   the rest — count against the bill, not just marginal Watt·s/token.
3. **search** (:func:`plan_fleet`) — exact enumeration of the count
   lattice when it is small, deterministic greedy beam search over
   +1-instance expansions otherwise, maximizing served tokens/s subject
   to budget and per-tenant SLO feasibility, tie-breaking on the full
   Watt·s/1k bill then catalog order.
4. **frontier** (:func:`cost_of_capacity_frontier`) — the plan re-run
   across ascending watt budgets yields the cost-of-capacity curve
   (served tokens/s vs provisioned watts, with the chosen mix per point)
   that ``benchmarks/provision_bench.py`` emits as
   ``BENCH_provision.json``. Feasible sets nest as budgets grow, so the
   curve is monotone non-decreasing in served tokens/s — enforced by
   carrying a better smaller-budget build forward, and pinned by the
   property tests.

Everything downstream of the (deterministic) sweep is pure arithmetic over
frozen dataclasses: the same forecast + catalog + budget always returns
the identical plan, byte for byte.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Optional, Sequence

from repro.configs.destinations import DestinationSpec
from repro.core.cache_store import PersistentEvalCache
from repro.core.evaluator import EvalEngine, VectorizedExecutor
from repro.core.fitness import UserRequirement
from repro.core.ga import GAConfig
from repro.core.offload_search import CellSpec, FleetResult, search_fleet
from repro.core.pareto import (
    CapacityPoint, allocate_demand, provision_awake_set,
    select_operating_point,
)
from repro.provision.budget import Budget
from repro.workload.forecast import WorkloadForecast

# The serving kinds a build is priced on (import indirection avoided: the
# runtime placement catalog uses the same two production shapes).
PROVISION_KINDS = ("prefill", "decode")


# ---------------------------------------------------------------------------
# Destination economics (one shared sweep, GA + Pareto operating points)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class KindRate:
    """One kind's chosen operating point on one destination, per token."""

    kind: str
    energy_per_token_ws: float
    time_per_token_s: float


@dataclass(frozen=True)
class DestinationEconomics:
    """Everything the multiset search needs to price one destination type."""

    spec: DestinationSpec
    order: int  # catalog position: the deterministic tie-break
    slots: int
    rates: tuple[KindRate, ...]

    @property
    def name(self) -> str:
        return self.spec.name

    def rate(self, kind: str) -> KindRate:
        for r in self.rates:
            if r.kind == kind:
                return r
        raise KeyError(f"{self.name} has no {kind!r} operating point")

    @property
    def capacity_tps(self) -> float:
        """Sustainable token throughput of ONE instance: slots over the
        slowest per-token step time (mirrors the router's
        ``engine_capacity_tps`` — a full engine emits one token per slot
        per step)."""
        worst = max(r.time_per_token_s for r in self.rates)
        return self.slots / worst if worst > 0.0 else 0.0

    def mix_energy_per_token_ws(self, prefill_frac: float) -> float:
        """Marginal Watt·s/token under the forecast prefill/decode mix."""
        return (prefill_frac * self.rate("prefill").energy_per_token_ws
                + (1.0 - prefill_frac)
                * self.rate("decode").energy_per_token_ws)

    def request_latency_s(self, prompt_tokens: int, new_tokens: int) -> float:
        """Modeled completion latency of one request on an unloaded
        instance (same accounting as the router's marginal estimate: the
        step consuming the last prompt token already emits the first
        output token)."""
        return (prompt_tokens * self.rate("prefill").time_per_token_s
                + max(new_tokens - 1, 0)
                * self.rate("decode").time_per_token_s)

    def to_json(self) -> dict:
        return {
            "name": self.name,
            "chips": self.spec.chips,
            "area": self.spec.area,
            "idle_watts": self.spec.idle_watts,
            "peak_watts": self.spec.peak_watts,
            "capacity_tps": self.capacity_tps,
            "rates": {r.kind: {"energy_per_token_ws": r.energy_per_token_ws,
                               "time_per_token_s": r.time_per_token_s}
                      for r in self.rates},
        }


@dataclass
class EconomicsResult:
    """The priced catalog plus the sweep it came from."""

    economics: list[DestinationEconomics]
    fleet: FleetResult
    skipped: dict[str, str]  # destination -> why it cannot be built

    @property
    def new_measurements(self) -> int:
        """Distinct measurements this sweep actually performed (0 on a
        cached re-plan — the determinism contract)."""
        return self.fleet.evaluations

    def by_name(self) -> dict[str, DestinationEconomics]:
        return {e.name: e for e in self.economics}


def destination_economics(
    arch: str,
    destinations: Sequence[DestinationSpec],
    *,
    shapes: dict,
    slots: int = 2,
    engine: Optional[EvalEngine] = None,
    cache_path: Optional[str] = None,
    ga_config: Optional[GAConfig] = None,
    requirement: Optional[UserRequirement] = None,
    cell_workers: int = 1,
    screen: bool = True,
) -> EconomicsResult:
    """Price every destination type with one shared ``search_fleet`` sweep.

    ``shapes`` maps each provisioning kind ("prefill"/"decode") to the
    production :class:`ShapeSpec` it is priced on (the router's
    ``DEFAULT_CATALOG`` is the usual argument). Cells carry each
    destination's own power model (the ``@pw:`` namespace keeps results
    apart); the per-cell energy-minimal frontier point — narrowed by
    ``requirement`` when given — becomes the destination's per-token rate.
    A destination whose cell was screened infeasible, or whose frontier
    has no point satisfying the requirement, is excluded from the build
    catalog and recorded in ``skipped``.
    """
    from repro.analysis.screen import ScreenPolicy

    eng = engine
    if eng is None:
        if cache_path:
            eng = EvalEngine(executor=VectorizedExecutor(),
                             cache=PersistentEvalCache(cache_path))
        else:
            eng = EvalEngine(executor=VectorizedExecutor())
    cells: dict[tuple[str, str], CellSpec] = {}
    for kind in PROVISION_KINDS:
        shape = shapes[kind]
        for d in destinations:
            cells[(kind, d.name)] = CellSpec.create(
                arch, shape, d.mesh_shape, power=d.power)
    # dominance pruning OFF: (time, energy)-dominated cells can still win
    # per provisioned watt; only provably infeasible cells are dropped
    policy = ScreenPolicy(dominance=False) if screen else None
    fleet = search_fleet(list(cells.values()), ga_config=ga_config,
                         engine=eng, cell_workers=cell_workers,
                         screen=policy)
    by_cell = fleet.by_cell()

    economics: list[DestinationEconomics] = []
    skipped: dict[str, str] = {}
    for order, d in enumerate(destinations):
        rates: list[KindRate] = []
        why = None
        for kind in PROVISION_KINDS:
            spec = cells[(kind, d.name)]
            cr = by_cell.get(spec.key)
            if cr is None:
                why = f"{kind} cell screened infeasible"
                break
            pt = select_operating_point(cr.search.frontier, requirement,
                                        prefer="energy")
            if pt is None:
                why = f"no {kind} operating point satisfies the requirement"
                break
            tokens = max(cr.spec.shape.tokens(), 1)
            rates.append(KindRate(kind=kind,
                                  energy_per_token_ws=pt.energy_ws / tokens,
                                  time_per_token_s=pt.time_s / tokens))
        if why is not None:
            skipped[d.name] = why
            continue
        economics.append(DestinationEconomics(
            spec=d, order=order, slots=slots, rates=tuple(rates)))
    return EconomicsResult(economics=economics, fleet=fleet, skipped=skipped)


# ---------------------------------------------------------------------------
# Fleet genomes (multisets of destination counts) and their evaluation
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class FleetGenome:
    """One candidate build: how many instances of each destination type.

    ``counts`` is canonical — catalog order, zero counts omitted — so equal
    builds compare and hash equal and the search's visited-set works."""

    counts: tuple[tuple[str, int], ...]

    @staticmethod
    def create(counts: dict, order: Sequence[str]) -> "FleetGenome":
        missing = set(counts) - set(order)
        if missing:
            raise ValueError(f"unknown destination types {sorted(missing)}")
        return FleetGenome(tuple((n, int(counts[n])) for n in order
                                 if counts.get(n, 0) > 0))

    def count(self, name: str) -> int:
        for n, c in self.counts:
            if n == name:
                return c
        return 0

    @property
    def total(self) -> int:
        return sum(c for _, c in self.counts)

    @property
    def label(self) -> str:
        if not self.counts:
            return "(nothing)"
        return "+".join(f"{c}x{n}" for n, c in self.counts)

    def as_dict(self) -> dict[str, int]:
        return dict(self.counts)


@dataclass(frozen=True)
class FleetEvaluation:
    """One candidate build, scored against a budget and a forecast."""

    genome: FleetGenome
    provisioned_watts: float  # nameplate: what must be built
    provisioned_area: float
    capacity_tps: float  # combined sustainable throughput
    served_tps: float  # min(forecast peak, capacity) — the objective
    mean_served_tps: float  # min(forecast mean, capacity) — the bill's rate
    power_w: float  # average draw serving the mean rate (full bill)
    ws_per_1k: float  # power_w / mean_served_tps * 1000
    slo_ok: bool
    within_budget: bool
    awake: tuple[str, ...]  # instances the mean rate keeps provisioned

    @property
    def feasible(self) -> bool:
        return self.within_budget and self.slo_ok and self.genome.total > 0

    def sort_key(self) -> tuple:
        """Deterministic preference: SLO-holding first, most served
        tokens/s, cheapest full bill, least nameplate watts, then the
        canonical counts tuple so exact ties are stable."""
        return (not self.slo_ok, -self.served_tps, self.ws_per_1k,
                self.provisioned_watts, self.genome.counts)

    def to_json(self) -> dict:
        return {
            "mix": self.genome.as_dict(),
            "label": self.genome.label,
            "provisioned_watts": self.provisioned_watts,
            "provisioned_area": self.provisioned_area,
            "capacity_tps": self.capacity_tps,
            "served_tps": self.served_tps,
            "mean_served_tps": self.mean_served_tps,
            "power_w": self.power_w,
            "ws_per_1k": self.ws_per_1k,
            "slo_ok": self.slo_ok,
            "within_budget": self.within_budget,
            "awake": list(self.awake),
        }


def evaluate_fleet(
    genome: FleetGenome,
    economics: Sequence[DestinationEconomics],
    budget: Budget,
    forecast: WorkloadForecast,
    *,
    min_awake: int = 1,
    headroom: float = 1.0,
) -> FleetEvaluation:
    """Score one candidate build.

    Nameplate watts/area debit the budget. The serving bill at the
    forecast mean rate reuses the PR 6 power-state economics: per-instance
    :class:`CapacityPoint`s are provisioned with
    :func:`~repro.core.pareto.provision_awake_set` (amortized
    Watt·s/token ranking), demand is split by
    :func:`~repro.core.pareto.allocate_demand`, provisioned instances
    bill their full idle floor, and the rest bill their deep-sleep
    fraction — an over-built fleet pays for every instance it stood up,
    which is the whole point of budgeted provisioning. SLO feasibility
    asks, per SLO'd tenant, for at least one built type whose modeled
    median-request latency fits the tenant's completion SLO.
    """
    by_name = {e.name: e for e in economics}
    watts = area = capacity = 0.0
    points: list[CapacityPoint] = []
    idle_by_instance: dict[str, float] = {}
    mix_e: dict[str, float] = {}
    for name, count in genome.counts:
        e = by_name[name]
        watts += count * e.spec.peak_watts
        area += count * e.spec.area
        capacity += count * e.capacity_tps
        mix_e[name] = e.mix_energy_per_token_ws(forecast.prefill_frac)
        for i in range(count):
            iname = f"{name}:{i}"
            points.append(CapacityPoint(
                name=iname, energy_per_token_ws=mix_e[name],
                static_watts=e.spec.idle_watts,
                capacity_tps=e.capacity_tps,
                order=e.order * 4096 + i))
            idle_by_instance[iname] = e.spec.idle_watts

    mean_served = min(forecast.mean_tps, capacity)
    served = min(forecast.peak_tps, capacity)

    awake: tuple[str, ...] = ()
    power_w = 0.0
    if points:
        awake = tuple(provision_awake_set(
            points, forecast.mean_tps,
            min_awake=min(max(min_awake, 1), len(points)),
            headroom=headroom))
        awake_set = set(awake)
        awake_points = [p for p in points if p.name in awake_set]
        alloc = allocate_demand(awake_points, mean_served)
        for p in awake_points:
            power_w += alloc.get(p.name, 0.0) * p.energy_per_token_ws
            power_w += p.static_watts
        sleep_fracs = {e.name: e.spec.sleep_frac for e in economics}
        for iname, idle in idle_by_instance.items():
            if iname not in awake_set:
                power_w += sleep_fracs[iname.rsplit(":", 1)[0]] * idle

    slo_ok = True
    for tenant in forecast.slo_tenants():
        fits = any(
            by_name[name].request_latency_s(
                tenant.prompt_median, tenant.new_tokens_median)
            <= tenant.slo_s
            for name, _ in genome.counts)
        if not fits:
            slo_ok = False
            break

    return FleetEvaluation(
        genome=genome,
        provisioned_watts=watts,
        provisioned_area=area,
        capacity_tps=capacity,
        served_tps=served,
        mean_served_tps=mean_served,
        power_w=power_w,
        ws_per_1k=(power_w / mean_served * 1000.0
                   if mean_served > 0.0 else float("inf")),
        slo_ok=slo_ok,
        within_budget=budget.admits(watts, area),
        awake=awake)


# ---------------------------------------------------------------------------
# Multiset search (exact enumeration or deterministic beam)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class SearchPolicy:
    """Knobs for the count-lattice search.

    ``max_enumeration`` bounds the exact walk of the count lattice
    (product of per-type cap+1); larger spaces fall back to the greedy
    beam over +1-instance expansions. Both are fully deterministic."""

    max_enumeration: int = 20_000
    beam_width: int = 8
    max_count_per_type: int = 64
    min_awake: int = 1
    headroom: float = 1.0


@dataclass
class ProvisionResult:
    """The recommendation plus how the search got there."""

    best: Optional[FleetEvaluation]  # None: nothing buildable under budget
    budget: Budget
    method: str  # "exact" | "beam"
    evaluated: int  # candidate builds scored
    caps: dict[str, int]  # per-type count ceiling the budget implied

    @property
    def counts(self) -> dict[str, int]:
        return self.best.genome.as_dict() if self.best else {}

    def destinations(self, catalog: dict[str, DestinationSpec]
                     ) -> list[DestinationSpec]:
        """Expand the recommended multiset into the (repeating) destination
        list a :class:`~repro.runtime.router.FleetRouter` takes."""
        out: list[DestinationSpec] = []
        if self.best:
            for name, count in self.best.genome.counts:
                out.extend([catalog[name]] * count)
        return out

    def to_json(self) -> dict:
        return {
            "best": self.best.to_json() if self.best else None,
            "budget": {"watts": self.budget.watts, "area": self.budget.area,
                       "count_caps": dict(self.budget.count_caps)},
            "method": self.method,
            "evaluated": self.evaluated,
            "caps": dict(self.caps),
        }


def _type_caps(economics: Sequence[DestinationEconomics], budget: Budget,
               policy: SearchPolicy) -> dict[str, int]:
    """Per-type count ceilings the budget implies (0 = cannot build one)."""
    caps: dict[str, int] = {}
    for e in economics:
        cap = policy.max_count_per_type
        if e.spec.peak_watts > 0.0:
            cap = min(cap, int(budget.watts // e.spec.peak_watts))
        if budget.area is not None and e.spec.area > 0.0:
            cap = min(cap, int(budget.area // e.spec.area))
        caps[e.name] = max(min(cap, budget.cap(e.name, cap)), 0)
    return caps


def plan_fleet(
    economics: Sequence[DestinationEconomics],
    budget: Budget,
    forecast: WorkloadForecast,
    *,
    policy: SearchPolicy = SearchPolicy(),
) -> ProvisionResult:
    """Search the destination-count multiset space under ``budget``.

    Exact enumeration walks the whole count lattice when it is small
    enough; otherwise a greedy beam grows builds one instance at a time,
    keeping the ``beam_width`` best-scoring partial builds per level.
    Either way the best build maximizes served tokens/s among SLO-feasible
    within-budget candidates (SLO-infeasible builds rank strictly after
    every SLO-holding one), tie-breaking on the full Watt·s/1k bill, then
    nameplate watts, then the canonical counts tuple — fully
    deterministic. ``best=None`` means the budget cannot stand up even one
    instance of any type."""
    econ = list(economics)
    caps = _type_caps(econ, budget, policy)
    names = [e.name for e in econ]

    def score(genome: FleetGenome) -> FleetEvaluation:
        return evaluate_fleet(genome, econ, budget, forecast,
                              min_awake=policy.min_awake,
                              headroom=policy.headroom)

    best: Optional[FleetEvaluation] = None
    evaluated = 0

    def consider(ev: FleetEvaluation) -> None:
        nonlocal best
        if not ev.within_budget or ev.genome.total == 0:
            return
        if best is None or ev.sort_key() < best.sort_key():
            best = ev

    space = 1
    for n in names:
        space *= caps[n] + 1
    if space <= policy.max_enumeration:
        method = "exact"
        for combo in itertools.product(
                *(range(caps[n] + 1) for n in names)):
            genome = FleetGenome(tuple(
                (n, c) for n, c in zip(names, combo) if c > 0))
            if genome.total == 0:
                continue
            ev = score(genome)
            evaluated += 1
            consider(ev)
    else:
        method = "beam"
        beam: list[tuple[tuple, FleetGenome]] = [((), FleetGenome(()))]
        seen: set[tuple[tuple[str, int], ...]] = {()}
        while beam:
            level: list[tuple[tuple, FleetGenome]] = []
            for _, genome in beam:
                base = genome.as_dict()
                for n in names:
                    if base.get(n, 0) >= caps[n]:
                        continue
                    grown = dict(base)
                    grown[n] = grown.get(n, 0) + 1
                    g2 = FleetGenome.create(grown, names)
                    if g2.counts in seen:
                        continue
                    seen.add(g2.counts)
                    ev = score(g2)
                    evaluated += 1
                    if not ev.within_budget:
                        continue
                    consider(ev)
                    level.append((ev.sort_key(), g2))
            level.sort(key=lambda item: item[0])
            beam = level[:policy.beam_width]

    return ProvisionResult(best=best, budget=budget, method=method,
                           evaluated=evaluated, caps=caps)


# ---------------------------------------------------------------------------
# Cost-of-capacity frontier
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class FrontierPoint:
    """One point on the cost-of-capacity curve: the best build at one
    watt-budget level."""

    budget_w: float
    provisioned_watts: float
    served_tps: float
    ws_per_1k: float
    slo_ok: bool
    mix: tuple[tuple[str, int], ...]

    def to_json(self) -> dict:
        return {
            "budget_w": self.budget_w,
            "provisioned_watts": self.provisioned_watts,
            "served_tps": self.served_tps,
            "ws_per_1k": self.ws_per_1k,
            "slo_ok": self.slo_ok,
            "mix": dict(self.mix),
        }


def cost_of_capacity_frontier(
    economics: Sequence[DestinationEconomics],
    budgets_w: Sequence[float],
    forecast: WorkloadForecast,
    *,
    area: Optional[float] = None,
    count_caps: Optional[dict] = None,
    policy: SearchPolicy = SearchPolicy(),
) -> list[FrontierPoint]:
    """Plan at each ascending watt budget; emit (tokens/s vs provisioned
    watts) with the chosen mix per point. Budget levels where nothing is
    buildable produce no point. Feasible sets nest as the budget grows, so
    served tokens/s is monotone non-decreasing along the curve; if a
    larger budget's (beam) search ever surfaces a worse build than a
    smaller budget already found, the smaller budget's build — still
    affordable — is carried forward instead."""
    points: list[FrontierPoint] = []
    prev: Optional[FleetEvaluation] = None
    for w in sorted(budgets_w):
        result = plan_fleet(economics, Budget.create(
            w, area=area, count_caps=count_caps), forecast, policy=policy)
        ev = result.best
        if ev is None and prev is None:
            continue
        if ev is None or (prev is not None
                          and ev.sort_key() > prev.sort_key()):
            ev = prev  # a smaller budget's build still fits this one
        prev = ev
        points.append(FrontierPoint(
            budget_w=float(w),
            provisioned_watts=ev.provisioned_watts,
            served_tps=ev.served_tps,
            ws_per_1k=ev.ws_per_1k,
            slo_ok=ev.slo_ok,
            mix=ev.genome.counts))
    return points
