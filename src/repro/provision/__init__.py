"""Budgeted fleet provisioning: search which destinations to *build*.

Capacity planning one level above ``search_fleet``: price every
destination type with the per-cell GA + Pareto operating points (shared
persistent eval cache, measurement pre-screen), then search the multiset
space of destination counts under a watt/area :class:`Budget`, maximizing
served tokens/s against a :class:`~repro.workload.forecast.WorkloadForecast`
with the full power-state bill (idle floors of over-provisioned engines
included). ``cost_of_capacity_frontier`` sweeps ascending budgets into the
tokens/s-vs-provisioned-watts curve ``BENCH_provision.json`` reports.
"""
from repro.provision.budget import Budget
from repro.provision.planner import (
    PROVISION_KINDS, DestinationEconomics, EconomicsResult, FleetEvaluation,
    FleetGenome, FrontierPoint, KindRate, ProvisionResult, SearchPolicy,
    cost_of_capacity_frontier, destination_economics, evaluate_fleet,
    plan_fleet,
)

__all__ = [
    "Budget", "DestinationEconomics", "EconomicsResult", "FleetEvaluation",
    "FleetGenome", "FrontierPoint", "KindRate", "PROVISION_KINDS",
    "ProvisionResult", "SearchPolicy", "cost_of_capacity_frontier",
    "destination_economics", "evaluate_fleet", "plan_fleet",
]
