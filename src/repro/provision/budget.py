"""Provisioning budgets: what an operator may build, before any request.

lumos (SNIPPETS.md 1-3) frames heterogeneous design as allocating one
total power/area budget across core types and accelerators; this module is
that constraint surface for the destination catalog. A :class:`Budget`
bounds the **nameplate** cost of standing destinations up:

* ``watts`` — total provisioned watts, debited at each destination's
  ``peak_watts`` (every component at full utilization). Power delivery is
  built for the worst case, not the average — a slice that idles cheap
  still needs its peak wired, which is exactly why over-building shows up
  twice: once here, and again as idle Watt·s on the serving bill.
* ``area`` — optional total chip area (``DestinationSpec.area`` units,
  defaulting to chips); None = unconstrained.
* ``count_caps`` — optional per-destination-type count ceilings (supply
  limits, rack space, a type the operator refuses to buy more of).

Budgets are frozen and validated on construction; :meth:`admits` is the
single feasibility predicate the multiset search calls.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Mapping, Optional


@dataclass(frozen=True)
class Budget:
    """The build envelope a provisioning search must stay inside."""

    watts: float
    area: Optional[float] = None
    count_caps: tuple[tuple[str, int], ...] = ()

    def __post_init__(self) -> None:
        if self.watts <= 0.0:
            raise ValueError(f"Budget.watts = {self.watts} must be positive")
        if self.area is not None and self.area <= 0.0:
            raise ValueError(f"Budget.area = {self.area} must be positive "
                             "(or None for unconstrained)")
        for name, cap in self.count_caps:
            if cap < 0:
                raise ValueError(f"Budget count cap for {name!r} is {cap}; "
                                 "caps must be >= 0")
        names = [n for n, _ in self.count_caps]
        if len(names) != len(set(names)):
            raise ValueError(f"duplicate count caps in {names}")

    @staticmethod
    def create(watts: float, *, area: Optional[float] = None,
               count_caps: Optional[Mapping[str, int]] = None) -> "Budget":
        """Dict-friendly constructor (count caps sorted for a canonical,
        hashable representation)."""
        caps = tuple(sorted((count_caps or {}).items()))
        return Budget(watts=watts, area=area, count_caps=caps)

    def cap(self, name: str, default: int) -> int:
        """Count ceiling for one destination type (``default`` when the
        budget does not name it)."""
        for n, c in self.count_caps:
            if n == name:
                return c
        return default

    def admits(self, watts: float, area: float) -> bool:
        """Whether a fleet with this nameplate draw and die area fits."""
        if watts > self.watts:
            return False
        if self.area is not None and area > self.area:
            return False
        return True
